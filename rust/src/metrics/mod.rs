//! Per-future lifecycle instrumentation and supervision metrics.
//!
//! Drives the Figure-1 schedule trace (`examples/figure1_trace.rs`) and the
//! overhead benchmarks: each future records timestamped lifecycle events
//! (create → launch → resolved → collect), and a process-global trace log
//! collects them for later rendering.
//!
//! Supervision counters are **keyed per session** (the first-class
//! [`crate::api::session::Session`] contexts): every backend pool captures
//! its owning session's [`CounterScope`] at construction, so two tenants
//! running different plans in one process see independent
//! worker-death/respawn/retry counts — while the process-wide totals stay
//! monotonic for the historical [`supervision_counters`] API.
//! [`supervision_json`] renders the whole picture in a stable JSON schema.

use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{SystemTime, UNIX_EPOCH};

// ------------------------------------------------- supervision counters ----

/// Process-wide fault-tolerance counters (monotonic; relaxed atomics — one
/// uncontended add per event, nothing on the task hot path).  Per-session
/// scopes add to these totals as well, so the global view never regresses.
static WORKER_DEATHS: AtomicU64 = AtomicU64::new(0);
static RESPAWNS: AtomicU64 = AtomicU64::new(0);
static RETRIES: AtomicU64 = AtomicU64::new(0);
static STALLS: AtomicU64 = AtomicU64::new(0);
static TIMEOUTS: AtomicU64 = AtomicU64::new(0);
static CANCELS: AtomicU64 = AtomicU64::new(0);
static FENCED_RESULTS: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the supervision counters.  Monotonic — tests compare
/// before/after deltas instead of resetting (safe under parallel tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SupervisionCounters {
    /// Workers observed dead (reader EOF, thread death, job crash).
    pub worker_deaths: u64,
    /// Replacement workers brought up (health monitor or the launch
    /// path's on-demand respawn — one shared budget either way).
    pub respawns: u64,
    /// Task resubmissions performed by supervised handles.
    pub retries: u64,
    /// Busy workers declared *hung* by the stall detector (no liveness
    /// signal for `stall_after`) and killed.
    pub stalls: u64,
    /// Futures whose deadline expired before resolution.
    pub timeouts: u64,
    /// Futures cancelled before resolution (user intent or deadline expiry).
    pub cancels: u64,
    /// Result frames dropped because their attempt epoch did not match the
    /// handle's current attempt (the stale-result fence).
    pub fenced_results: u64,
}

struct ScopeInner {
    session: u64,
    deaths: AtomicU64,
    respawns: AtomicU64,
    retries: AtomicU64,
    stalls: AtomicU64,
    timeouts: AtomicU64,
    cancels: AtomicU64,
    fenced: AtomicU64,
}

impl ScopeInner {
    fn new(session: u64) -> Self {
        ScopeInner {
            session,
            deaths: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            retries: AtomicU64::new(0),
            stalls: AtomicU64::new(0),
            timeouts: AtomicU64::new(0),
            cancels: AtomicU64::new(0),
            fenced: AtomicU64::new(0),
        }
    }
}

/// A session-attributed counter sink.  Backends capture the scope of the
/// session that constructed them ([`ambient_scope`] at construction time)
/// and record against it from monitor/reader threads; every record also
/// bumps the process-wide totals.
#[derive(Clone)]
pub struct CounterScope {
    inner: Arc<ScopeInner>,
}

impl CounterScope {
    /// The session this scope attributes to (0 = the default session).
    pub fn session(&self) -> u64 {
        self.inner.session
    }

    /// A backend observed a worker die outside an orderly shutdown.
    pub fn worker_death(&self) {
        self.inner.deaths.fetch_add(1, Ordering::Relaxed);
        WORKER_DEATHS.fetch_add(1, Ordering::Relaxed);
    }

    /// A replacement worker was brought up (monitor or on-demand).
    pub fn respawn(&self) {
        self.inner.respawns.fetch_add(1, Ordering::Relaxed);
        RESPAWNS.fetch_add(1, Ordering::Relaxed);
    }

    /// A supervised handle resubmitted a task after infrastructure loss.
    pub fn retry(&self) {
        self.inner.retries.fetch_add(1, Ordering::Relaxed);
        RETRIES.fetch_add(1, Ordering::Relaxed);
    }

    /// The stall detector declared a busy worker hung and killed it.
    pub fn stall(&self) {
        self.inner.stalls.fetch_add(1, Ordering::Relaxed);
        STALLS.fetch_add(1, Ordering::Relaxed);
    }

    /// A future's deadline expired before resolution.
    pub fn timeout(&self) {
        self.inner.timeouts.fetch_add(1, Ordering::Relaxed);
        TIMEOUTS.fetch_add(1, Ordering::Relaxed);
    }

    /// A future was cancelled before resolution.
    pub fn cancel(&self) {
        self.inner.cancels.fetch_add(1, Ordering::Relaxed);
        CANCELS.fetch_add(1, Ordering::Relaxed);
    }

    /// A stale result frame (attempt-epoch mismatch) was dropped.
    pub fn fenced(&self) {
        self.inner.fenced.fetch_add(1, Ordering::Relaxed);
        FENCED_RESULTS.fetch_add(1, Ordering::Relaxed);
    }

    /// Snapshot of this scope's (session-local) counters.
    pub fn counters(&self) -> SupervisionCounters {
        SupervisionCounters {
            worker_deaths: self.inner.deaths.load(Ordering::Relaxed),
            respawns: self.inner.respawns.load(Ordering::Relaxed),
            retries: self.inner.retries.load(Ordering::Relaxed),
            stalls: self.inner.stalls.load(Ordering::Relaxed),
            timeouts: self.inner.timeouts.load(Ordering::Relaxed),
            cancels: self.inner.cancels.load(Ordering::Relaxed),
            fenced_results: self.inner.fenced.load(Ordering::Relaxed),
        }
    }
}

/// session id → scope, created on first use.
static SCOPES: Mutex<Option<HashMap<u64, CounterScope>>> = Mutex::new(None);

/// The counter scope attributed to `session` (created on demand; one per
/// session id for the process lifetime — scopes are tiny).
pub fn scope_for_session(session: u64) -> CounterScope {
    let mut guard = SCOPES.lock().unwrap();
    guard
        .get_or_insert_with(HashMap::new)
        .entry(session)
        .or_insert_with(|| CounterScope { inner: Arc::new(ScopeInner::new(session)) })
        .clone()
}

/// The default session's scope (session id 0) — where the legacy free
/// functions and scope-less call sites record.
pub fn default_scope() -> CounterScope {
    scope_for_session(0)
}

/// A scope that attributes to `session` but is NOT entered into the
/// registry — for work racing a closed session, so eviction is not
/// undone.  Records still feed the process-wide totals.
pub fn detached_scope(session: u64) -> CounterScope {
    CounterScope { inner: Arc::new(ScopeInner::new(session)) }
}

/// Evict a session's registry entry (called by `Session::close`).  Live
/// `CounterScope` clones held by pools/handles keep working — only the
/// per-session enumeration ([`session_supervision_counters`],
/// [`all_session_counters`], [`supervision_json`]) forgets the session;
/// the process-wide totals are separate statics and never regress.
pub fn drop_session_scope(session: u64) {
    if let Some(map) = SCOPES.lock().unwrap().as_mut() {
        map.remove(&session);
    }
    if let Some(map) = ANALYSIS.lock().unwrap().as_mut() {
        map.remove(&session);
    }
    // Frees the session's in-memory result-cache tier too (its counters
    // fold into the process totals; disk objects persist by design).
    crate::cache::clear_session(session);
}

/// Per-session snapshot (all zeros for a session that never recorded).
pub fn session_supervision_counters(session: u64) -> SupervisionCounters {
    let guard = SCOPES.lock().unwrap();
    guard
        .as_ref()
        .and_then(|m| m.get(&session))
        .map(|s| s.counters())
        .unwrap_or_default()
}

/// Every session that has a scope, with its counters, sorted by session id.
pub fn all_session_counters() -> Vec<(u64, SupervisionCounters)> {
    let guard = SCOPES.lock().unwrap();
    let mut out: Vec<(u64, SupervisionCounters)> = guard
        .as_ref()
        .map(|m| m.iter().map(|(id, s)| (*id, s.counters())).collect())
        .unwrap_or_default();
    out.sort_by_key(|(id, _)| *id);
    out
}

thread_local! {
    /// Ambient scope stack: [`crate::api::session::Session`] pushes its
    /// scope around backend construction so pools capture the right sink.
    static AMBIENT: RefCell<Vec<CounterScope>> = const { RefCell::new(Vec::new()) };
}

/// Guard returned by [`push_ambient_scope`]; pops on drop (panic-safe).
pub struct AmbientScopeGuard {
    _private: (),
}

impl Drop for AmbientScopeGuard {
    fn drop(&mut self) {
        AMBIENT.with(|s| {
            s.borrow_mut().pop();
        });
    }
}

/// Install `scope` as the ambient counter sink for this thread until the
/// guard drops.  Backend constructors read it via [`ambient_scope`].
pub fn push_ambient_scope(scope: CounterScope) -> AmbientScopeGuard {
    AMBIENT.with(|s| s.borrow_mut().push(scope));
    AmbientScopeGuard { _private: () }
}

/// The scope a backend being constructed on this thread should record to:
/// the innermost pushed scope, else the default session's.
pub fn ambient_scope() -> CounterScope {
    AMBIENT
        .with(|s| s.borrow().last().cloned())
        .unwrap_or_else(default_scope)
}

/// Legacy free function: record against the default session.
pub fn record_worker_death() {
    default_scope().worker_death();
}

/// Legacy free function: record against the default session.
pub fn record_respawn() {
    default_scope().respawn();
}

/// Legacy free function: record against the default session.
pub fn record_retry() {
    default_scope().retry();
}

/// Process-wide totals across every session (monotonic).
pub fn supervision_counters() -> SupervisionCounters {
    SupervisionCounters {
        worker_deaths: WORKER_DEATHS.load(Ordering::Relaxed),
        respawns: RESPAWNS.load(Ordering::Relaxed),
        retries: RETRIES.load(Ordering::Relaxed),
        stalls: STALLS.load(Ordering::Relaxed),
        timeouts: TIMEOUTS.load(Ordering::Relaxed),
        cancels: CANCELS.load(Ordering::Relaxed),
        fenced_results: FENCED_RESULTS.load(Ordering::Relaxed),
    }
}

fn counters_json(c: &SupervisionCounters, session: Option<u64>, out: &mut String) {
    out.push('{');
    if let Some(id) = session {
        out.push_str(&format!("\"session\":{id},"));
    }
    out.push_str(&format!(
        "\"worker_deaths\":{},\"respawns\":{},\"retries\":{},\"liveness\":{{\"stalls\":{},\"timeouts\":{},\"cancels\":{},\"fenced_results\":{}}}",
        c.worker_deaths, c.respawns, c.retries, c.stalls, c.timeouts, c.cancels, c.fenced_results
    ));
    out.push('}');
}

/// The supervision counters as JSON, keyed per session — the trace/metrics
/// schema surface (`rustures.supervision.v1`):
///
/// ```json
/// {"schema":"rustures.supervision.v1",
///  "total":{"worker_deaths":2,"respawns":2,"retries":1},
///  "sessions":[{"session":0,"worker_deaths":1,"respawns":1,"retries":0},
///              {"session":3,"worker_deaths":1,"respawns":1,"retries":1}]}
/// ```
pub fn supervision_json() -> String {
    let mut out = String::from("{\"schema\":\"rustures.supervision.v1\",\"total\":");
    counters_json(&supervision_counters(), None, &mut out);
    out.push_str(",\"sessions\":[");
    for (i, (id, c)) in all_session_counters().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        counters_json(c, Some(*id), &mut out);
    }
    out.push_str("]}");
    out
}

/// Per-pool, per-host, per-session execution-slot utilization as JSON —
/// the capacity ledger's metrics surface (schema `rustures.capacity.v1`;
/// see [`crate::capacity::capacity_json`] for the shape).
pub fn capacity_json() -> String {
    crate::capacity::capacity_json()
}

/// Result-cache utilization as JSON — hits/misses/publishes/evictions/bytes
/// per tier per session (schema `rustures.cache.v1`; see
/// [`crate::cache::cache_json`] for the shape).
pub fn cache_json() -> String {
    crate::cache::cache_json()
}

/// Transport-reactor counters and per-channel outbox gauges as JSON — the
/// async multiplexed transport core's metrics surface (schema
/// `rustures.transport.v1`):
///
/// ```json
/// {"schema":"rustures.transport.v1",
///  "wakeups":812,"ready_events":1430,"timer_fires":2,
///  "frames_in":5210,"bytes_in":88211,"bytes_out":91724,
///  "pipeline":{"forwards":12,"prebinds":3},
///  "backpressure_waits":1,
///  "channels":{"open":8,"pump":0,"outbox_bytes":0,
///              "outboxes":[{"name":"procpool-1","queued":0}]}}
/// ```
///
/// Counters are monotonic process totals; `channels` is a point-in-time
/// gauge (empty before the reactor's first channel registers).
pub fn transport_json() -> String {
    let s = crate::transport::stats();
    let mut out = String::from("{\"schema\":\"rustures.transport.v1\",");
    out.push_str(&format!(
        "\"wakeups\":{},\"ready_events\":{},\"timer_fires\":{},\"frames_in\":{},\"bytes_in\":{},\"bytes_out\":{},",
        s.wakeups, s.ready_events, s.timer_fires, s.frames_in, s.bytes_in, s.bytes_out
    ));
    out.push_str(&format!(
        "\"pipeline\":{{\"forwards\":{},\"prebinds\":{}}},\"backpressure_waits\":{},",
        s.forwards, s.prebinds, s.backpressure_waits
    ));
    out.push_str(&format!(
        "\"channels\":{{\"open\":{},\"pump\":{},\"outbox_bytes\":{},\"outboxes\":[",
        s.channels_open, s.channels_pump, s.outbox_bytes
    ));
    for (i, (name, queued)) in crate::transport::per_channel_outbox().iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        let name = crate::util::json::to_string(&crate::util::json::Json::Str(name.clone()));
        out.push_str(&format!("{{\"name\":{name},\"queued\":{queued}}}"));
    }
    out.push_str("]}}");
    out
}

// --------------------------------------------------- analysis counters ----

/// Process-wide static-analysis totals (monotonic; mirror the per-session
/// cells the way the supervision statics mirror [`CounterScope`]s).
static ANALYSIS_DENIES: AtomicU64 = AtomicU64::new(0);
static ANALYSIS_WARNS: AtomicU64 = AtomicU64::new(0);

#[derive(Default)]
struct AnalysisCell {
    denies: u64,
    warns: u64,
    /// lint code → occurrences (denied + warned), sorted for stable JSON.
    codes: BTreeMap<String, u64>,
}

/// session id → analysis counters, created on first record.
static ANALYSIS: Mutex<Option<HashMap<u64, AnalysisCell>>> = Mutex::new(None);

/// Snapshot of one session's static-analysis counters.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct AnalysisCounters {
    /// Futures refused at creation (`FutureError::Rejected`); one count
    /// per denied diagnostic.
    pub denies: u64,
    /// Warn-severity diagnostics relayed at creation.
    pub warns: u64,
    /// Per-lint-code occurrence counts (denied + warned), sorted by code.
    pub codes: Vec<(String, u64)>,
}

/// Record one enforced diagnostic against `session` (the origin id).
/// Called by `future_with`; `Session::lint` never records.
pub fn record_analysis(session: u64, code: &str, denied: bool) {
    if denied {
        ANALYSIS_DENIES.fetch_add(1, Ordering::Relaxed);
    } else {
        ANALYSIS_WARNS.fetch_add(1, Ordering::Relaxed);
    }
    let mut guard = ANALYSIS.lock().unwrap();
    let cell = guard.get_or_insert_with(HashMap::new).entry(session).or_default();
    if denied {
        cell.denies += 1;
    } else {
        cell.warns += 1;
    }
    *cell.codes.entry(code.to_string()).or_insert(0) += 1;
}

/// Process-wide (denies, warns) totals across every session (monotonic).
pub fn analysis_totals() -> (u64, u64) {
    (ANALYSIS_DENIES.load(Ordering::Relaxed), ANALYSIS_WARNS.load(Ordering::Relaxed))
}

/// Per-session snapshot (all zeros for a session that never recorded).
pub fn session_analysis_counters(session: u64) -> AnalysisCounters {
    let guard = ANALYSIS.lock().unwrap();
    guard
        .as_ref()
        .and_then(|m| m.get(&session))
        .map(|c| AnalysisCounters {
            denies: c.denies,
            warns: c.warns,
            codes: c.codes.iter().map(|(k, v)| (k.clone(), *v)).collect(),
        })
        .unwrap_or_default()
}

/// The static-analysis counters as JSON, keyed per session — the metrics
/// schema surface (`rustures.analysis.v1`):
///
/// ```json
/// {"schema":"rustures.analysis.v1",
///  "total":{"denies":2,"warns":5},
///  "sessions":[{"session":3,"denies":2,"warns":0,
///               "codes":{"export-size":2}}]}
/// ```
pub fn analysis_json() -> String {
    let (denies, warns) = analysis_totals();
    let mut out = format!(
        "{{\"schema\":\"rustures.analysis.v1\",\"total\":{{\"denies\":{denies},\"warns\":{warns}}},\"sessions\":["
    );
    let guard = ANALYSIS.lock().unwrap();
    let mut ids: Vec<u64> =
        guard.as_ref().map(|m| m.keys().copied().collect()).unwrap_or_default();
    ids.sort_unstable();
    for (i, id) in ids.iter().enumerate() {
        let cell = &guard.as_ref().unwrap()[id];
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "{{\"session\":{id},\"denies\":{},\"warns\":{},\"codes\":{{",
            cell.denies, cell.warns
        ));
        for (j, (code, n)) in cell.codes.iter().enumerate() {
            if j > 0 {
                out.push(',');
            }
            out.push_str(&format!("\"{code}\":{n}"));
        }
        out.push_str("}}");
    }
    out.push_str("]}");
    out
}

fn now_ns() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_nanos() as u64
}

/// Timestamped lifecycle events of one future.
#[derive(Debug)]
pub struct FutureTrace {
    pub id: String,
    pub label: Option<String>,
    pub backend: &'static str,
    /// Owning session id (0 = default session).
    pub session: u64,
    pub created_ns: u64,
    events: Mutex<Vec<(String, u64)>>,
}

impl FutureTrace {
    pub fn new(
        id: &str,
        label: Option<&str>,
        backend: &'static str,
        session: u64,
        created_ns: u64,
    ) -> Self {
        FutureTrace {
            id: id.to_string(),
            label: label.map(str::to_string),
            backend,
            session,
            created_ns,
            events: Mutex::new(vec![("create".to_string(), created_ns)]),
        }
    }

    pub fn events(&self) -> Vec<(String, u64)> {
        self.events.lock().unwrap().clone()
    }

    /// Timestamp of the first event with this name, if recorded.
    pub fn event_ns(&self, name: &str) -> Option<u64> {
        self.events.lock().unwrap().iter().find(|(n, _)| n == name).map(|(_, t)| *t)
    }
}

/// Append a lifecycle event and mirror it into the session log (if enabled).
///
/// §Perf: this runs on every future's create/launch/resolve/collect, so the
/// session-log mirror — two *global* lock acquisitions — is gated behind one
/// relaxed atomic load and costs nothing while tracing is off.  The
/// per-future `events` mutex remains (it is uncontended and per-trace, and
/// examples/tests read lifecycle timestamps without a session trace).
pub fn record_event(trace: &Arc<FutureTrace>, name: &str) {
    let t = now_ns();
    trace.events.lock().unwrap().push((name.to_string(), t));
    if !SESSION_ACTIVE.load(Ordering::Relaxed) {
        return;
    }
    let log = SESSION_LOG.lock().unwrap();
    if let Some(log) = &*log {
        log.lock().unwrap().push(TraceEvent {
            future_id: trace.id.clone(),
            label: trace.label.clone(),
            session: trace.session,
            event: name.to_string(),
            at_ns: t,
        });
    }
}

/// One row of the session trace log.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub future_id: String,
    pub label: Option<String>,
    /// Owning session of the traced future (trace schema key).
    pub session: u64,
    pub event: String,
    pub at_ns: u64,
}

type Log = Arc<Mutex<Vec<TraceEvent>>>;
static SESSION_LOG: Mutex<Option<Log>> = Mutex::new(None);
/// Fast-path gate for [`record_event`]: true iff a session trace is live.
static SESSION_ACTIVE: AtomicBool = AtomicBool::new(false);

/// Start collecting a session trace; returns the live log handle.
pub fn start_session_trace() -> Log {
    let log: Log = Arc::new(Mutex::new(Vec::new()));
    *SESSION_LOG.lock().unwrap() = Some(Arc::clone(&log));
    SESSION_ACTIVE.store(true, Ordering::Relaxed);
    log
}

/// Stop collecting and detach.
pub fn stop_session_trace() {
    SESSION_ACTIVE.store(false, Ordering::Relaxed);
    *SESSION_LOG.lock().unwrap() = None;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_records_events_in_order() {
        let t = Arc::new(FutureTrace::new("f1", Some("lbl"), "sequential", 0, now_ns()));
        record_event(&t, "launch");
        record_event(&t, "resolved");
        let events = t.events();
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].0, "create");
        assert_eq!(events[1].0, "launch");
        assert_eq!(events[2].0, "resolved");
        assert!(events[2].1 >= events[1].1);
        assert!(t.event_ns("launch").is_some());
        assert!(t.event_ns("nope").is_none());
    }

    #[test]
    fn supervision_counters_are_monotonic() {
        let before = supervision_counters();
        record_worker_death();
        record_respawn();
        record_retry();
        record_retry();
        let after = supervision_counters();
        assert!(after.worker_deaths >= before.worker_deaths + 1);
        assert!(after.respawns >= before.respawns + 1);
        assert!(after.retries >= before.retries + 2);
    }

    #[test]
    fn scopes_attribute_per_session_and_feed_totals() {
        // Use ids far from anything a real session would get in tests.
        let a = scope_for_session(9_000_001);
        let b = scope_for_session(9_000_002);
        let global_before = supervision_counters();
        a.worker_death();
        a.retry();
        let ac = session_supervision_counters(9_000_001);
        let bc = session_supervision_counters(9_000_002);
        assert_eq!(ac.worker_deaths, 1);
        assert_eq!(ac.retries, 1);
        assert_eq!(bc, SupervisionCounters::default(), "scopes must be isolated");
        let _ = b; // keep the scope registered
        let global_after = supervision_counters();
        assert!(global_after.worker_deaths >= global_before.worker_deaths + 1);
        assert!(global_after.retries >= global_before.retries + 1);
    }

    #[test]
    fn ambient_scope_stacks_and_defaults() {
        assert_eq!(ambient_scope().session(), 0, "default ambient is session 0");
        let s = scope_for_session(9_000_003);
        {
            let _g = push_ambient_scope(s.clone());
            assert_eq!(ambient_scope().session(), 9_000_003);
        }
        assert_eq!(ambient_scope().session(), 0, "guard must pop on drop");
    }

    #[test]
    fn supervision_json_has_schema_total_and_sessions() {
        let s = scope_for_session(9_000_004);
        s.respawn();
        let json = supervision_json();
        let doc = crate::util::json::parse(&json).expect("valid JSON");
        assert_eq!(
            doc.get("schema").and_then(|s| s.as_str()),
            Some("rustures.supervision.v1")
        );
        assert!(doc.get("total").and_then(|t| t.get("worker_deaths")).is_some());
        let sessions = doc.get("sessions").unwrap().as_arr().unwrap();
        let entry = sessions
            .iter()
            .find(|e| e.get("session").and_then(|v| v.as_i64()) == Some(9_000_004))
            .expect("session entry present");
        assert!(entry.get("respawns").unwrap().as_i64().unwrap() >= 1);
    }

    #[test]
    fn liveness_counters_attribute_and_render() {
        let s = scope_for_session(9_000_005);
        s.stall();
        s.timeout();
        s.cancel();
        s.cancel();
        s.fenced();
        let c = session_supervision_counters(9_000_005);
        assert_eq!((c.stalls, c.timeouts, c.cancels, c.fenced_results), (1, 1, 2, 1));
        let json = supervision_json();
        let doc = crate::util::json::parse(&json).expect("valid JSON");
        let sessions = doc.get("sessions").unwrap().as_arr().unwrap();
        let entry = sessions
            .iter()
            .find(|e| e.get("session").and_then(|v| v.as_i64()) == Some(9_000_005))
            .expect("session entry present");
        let lv = entry.get("liveness").expect("liveness object");
        assert_eq!(lv.get("stalls").unwrap().as_i64(), Some(1));
        assert_eq!(lv.get("cancels").unwrap().as_i64(), Some(2));
        assert_eq!(lv.get("fenced_results").unwrap().as_i64(), Some(1));
        let total = doc.get("total").unwrap().get("liveness").expect("total liveness");
        assert!(total.get("timeouts").unwrap().as_i64().unwrap() >= 1);
    }

    #[test]
    fn session_log_collects_across_futures() {
        let log = start_session_trace();
        let t1 = Arc::new(FutureTrace::new("a", None, "sequential", 7, now_ns()));
        let t2 = Arc::new(FutureTrace::new("b", None, "sequential", 7, now_ns()));
        record_event(&t1, "launch");
        record_event(&t2, "launch");
        stop_session_trace();
        record_event(&t1, "after-stop");
        let rows = log.lock().unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.event == "launch" && r.session == 7));
    }
}
