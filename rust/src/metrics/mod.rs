//! Per-future lifecycle instrumentation.
//!
//! Drives the Figure-1 schedule trace (`examples/figure1_trace.rs`) and the
//! overhead benchmarks: each future records timestamped lifecycle events
//! (create → launch → resolved → collect), and a process-global trace log
//! collects them for later rendering.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{SystemTime, UNIX_EPOCH};

// ------------------------------------------------- supervision counters ----

/// Process-wide fault-tolerance counters (monotonic; relaxed atomics — one
/// uncontended add per event, nothing on the task hot path).
static WORKER_DEATHS: AtomicU64 = AtomicU64::new(0);
static RESPAWNS: AtomicU64 = AtomicU64::new(0);
static RETRIES: AtomicU64 = AtomicU64::new(0);

/// Snapshot of the supervision counters.  Monotonic — tests compare
/// before/after deltas instead of resetting (safe under parallel tests).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct SupervisionCounters {
    /// Workers observed dead (reader EOF, thread death, job crash).
    pub worker_deaths: u64,
    /// Replacement workers brought up (health monitor or the launch
    /// path's on-demand respawn — one shared budget either way).
    pub respawns: u64,
    /// Task resubmissions performed by supervised handles.
    pub retries: u64,
}

/// A backend observed a worker die outside an orderly shutdown.
pub fn record_worker_death() {
    WORKER_DEATHS.fetch_add(1, Ordering::Relaxed);
}

/// A replacement worker was brought up (monitor or on-demand).
pub fn record_respawn() {
    RESPAWNS.fetch_add(1, Ordering::Relaxed);
}

/// A supervised handle resubmitted a task after infrastructure loss.
pub fn record_retry() {
    RETRIES.fetch_add(1, Ordering::Relaxed);
}

pub fn supervision_counters() -> SupervisionCounters {
    SupervisionCounters {
        worker_deaths: WORKER_DEATHS.load(Ordering::Relaxed),
        respawns: RESPAWNS.load(Ordering::Relaxed),
        retries: RETRIES.load(Ordering::Relaxed),
    }
}

fn now_ns() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_nanos() as u64
}

/// Timestamped lifecycle events of one future.
#[derive(Debug)]
pub struct FutureTrace {
    pub id: String,
    pub label: Option<String>,
    pub backend: &'static str,
    pub created_ns: u64,
    events: Mutex<Vec<(String, u64)>>,
}

impl FutureTrace {
    pub fn new(id: &str, label: Option<&str>, backend: &'static str, created_ns: u64) -> Self {
        FutureTrace {
            id: id.to_string(),
            label: label.map(str::to_string),
            backend,
            created_ns,
            events: Mutex::new(vec![("create".to_string(), created_ns)]),
        }
    }

    pub fn events(&self) -> Vec<(String, u64)> {
        self.events.lock().unwrap().clone()
    }

    /// Timestamp of the first event with this name, if recorded.
    pub fn event_ns(&self, name: &str) -> Option<u64> {
        self.events.lock().unwrap().iter().find(|(n, _)| n == name).map(|(_, t)| *t)
    }
}

/// Append a lifecycle event and mirror it into the session log (if enabled).
///
/// §Perf: this runs on every future's create/launch/resolve/collect, so the
/// session-log mirror — two *global* lock acquisitions — is gated behind one
/// relaxed atomic load and costs nothing while tracing is off.  The
/// per-future `events` mutex remains (it is uncontended and per-trace, and
/// examples/tests read lifecycle timestamps without a session trace).
pub fn record_event(trace: &Arc<FutureTrace>, name: &str) {
    let t = now_ns();
    trace.events.lock().unwrap().push((name.to_string(), t));
    if !SESSION_ACTIVE.load(Ordering::Relaxed) {
        return;
    }
    let log = SESSION_LOG.lock().unwrap();
    if let Some(log) = &*log {
        log.lock().unwrap().push(TraceEvent {
            future_id: trace.id.clone(),
            label: trace.label.clone(),
            event: name.to_string(),
            at_ns: t,
        });
    }
}

/// One row of the session trace log.
#[derive(Debug, Clone)]
pub struct TraceEvent {
    pub future_id: String,
    pub label: Option<String>,
    pub event: String,
    pub at_ns: u64,
}

type Log = Arc<Mutex<Vec<TraceEvent>>>;
static SESSION_LOG: Mutex<Option<Log>> = Mutex::new(None);
/// Fast-path gate for [`record_event`]: true iff a session trace is live.
static SESSION_ACTIVE: AtomicBool = AtomicBool::new(false);

/// Start collecting a session trace; returns the live log handle.
pub fn start_session_trace() -> Log {
    let log: Log = Arc::new(Mutex::new(Vec::new()));
    *SESSION_LOG.lock().unwrap() = Some(Arc::clone(&log));
    SESSION_ACTIVE.store(true, Ordering::Relaxed);
    log
}

/// Stop collecting and detach.
pub fn stop_session_trace() {
    SESSION_ACTIVE.store(false, Ordering::Relaxed);
    *SESSION_LOG.lock().unwrap() = None;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trace_records_events_in_order() {
        let t = Arc::new(FutureTrace::new("f1", Some("lbl"), "sequential", now_ns()));
        record_event(&t, "launch");
        record_event(&t, "resolved");
        let events = t.events();
        assert_eq!(events.len(), 3);
        assert_eq!(events[0].0, "create");
        assert_eq!(events[1].0, "launch");
        assert_eq!(events[2].0, "resolved");
        assert!(events[2].1 >= events[1].1);
        assert!(t.event_ns("launch").is_some());
        assert!(t.event_ns("nope").is_none());
    }

    #[test]
    fn supervision_counters_are_monotonic() {
        let before = supervision_counters();
        record_worker_death();
        record_respawn();
        record_retry();
        record_retry();
        let after = supervision_counters();
        assert!(after.worker_deaths >= before.worker_deaths + 1);
        assert!(after.respawns >= before.respawns + 1);
        assert!(after.retries >= before.retries + 2);
    }

    #[test]
    fn session_log_collects_across_futures() {
        let log = start_session_trace();
        let t1 = Arc::new(FutureTrace::new("a", None, "sequential", now_ns()));
        let t2 = Arc::new(FutureTrace::new("b", None, "sequential", now_ns()));
        record_event(&t1, "launch");
        record_event(&t2, "launch");
        stop_session_trace();
        record_event(&t1, "after-stop");
        let rows = log.lock().unwrap();
        assert_eq!(rows.len(), 2);
        assert!(rows.iter().all(|r| r.event == "launch"));
    }
}
