//! A minimal property-testing harness (proptest is unavailable in this
//! offline image — see DESIGN.md §Substitutions).
//!
//! Deterministic, seeded case generation over our own MRG32k3a; on failure
//! the panic message carries the seed and case index so the exact input
//! regenerates.  No shrinking — cases are kept small instead.

use crate::api::rng::RngStream;

/// Input generator for one property case.
pub struct Gen {
    rng: RngStream,
}

impl Gen {
    pub fn new(seed: u64, case: u64) -> Self {
        Gen { rng: RngStream::nth_stream(seed, case) }
    }

    /// Uniform usize in `[lo, hi]` (inclusive).
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo <= hi);
        let span = (hi - lo + 1) as f64;
        lo + (self.rng.next_unif() * span) as usize
    }

    pub fn u64(&mut self) -> u64 {
        // Two 26-bit chunks + one 12-bit chunk from uniform draws.
        let a = (self.rng.next_unif() * (1u64 << 26) as f64) as u64;
        let b = (self.rng.next_unif() * (1u64 << 26) as f64) as u64;
        let c = (self.rng.next_unif() * (1u64 << 12) as f64) as u64;
        (a << 38) | (b << 12) | c
    }

    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.rng.next_unif() * (hi - lo)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_unif() < 0.5
    }

    /// Pick an element of a slice.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize_in(0, items.len() - 1)]
    }

    /// A short lowercase identifier.
    pub fn ident(&mut self) -> String {
        let len = self.usize_in(1, 6);
        (0..len).map(|_| (b'a' + self.usize_in(0, 25) as u8) as char).collect()
    }

    /// Vector of values from a generator closure.
    pub fn vec<T>(&mut self, max_len: usize, mut f: impl FnMut(&mut Gen) -> T) -> Vec<T> {
        let len = self.usize_in(0, max_len);
        (0..len).map(|_| f(self)).collect()
    }
}

/// Run `cases` property cases; panic (with reproduction info) on the first
/// failure.  The property returns `Err(message)` to fail.
pub fn check(name: &str, cases: u64, prop: impl Fn(&mut Gen) -> Result<(), String>) {
    // Stable per-property seed so failures reproduce across runs.
    let seed = name.bytes().fold(0xcbf29ce484222325u64, |h, b| {
        (h ^ b as u64).wrapping_mul(0x100000001b3)
    });
    for case in 0..cases {
        let mut gen = Gen::new(seed, case);
        if let Err(msg) = prop(&mut gen) {
            panic!("property '{name}' failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generators_are_deterministic() {
        let mut a = Gen::new(1, 0);
        let mut b = Gen::new(1, 0);
        for _ in 0..10 {
            assert_eq!(a.u64(), b.u64());
        }
        let mut c = Gen::new(1, 1);
        assert_ne!(a.u64(), c.u64());
    }

    #[test]
    fn usize_in_respects_bounds() {
        let mut g = Gen::new(7, 0);
        for _ in 0..1000 {
            let v = g.usize_in(3, 9);
            assert!((3..=9).contains(&v));
        }
    }

    #[test]
    fn check_passes_trivial_property() {
        check("trivial", 50, |g| {
            let n = g.usize_in(0, 100);
            if n <= 100 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'failing' failed")]
    fn check_panics_with_repro_info() {
        check("failing", 10, |g| {
            if g.usize_in(0, 10) < 11 {
                Err("always fails".into())
            } else {
                Ok(())
            }
        });
    }
}
