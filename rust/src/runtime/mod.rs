//! PJRT runtime: load AOT artifacts and execute them from the request path.
//!
//! `make artifacts` lowers the L2 JAX graphs (which call the L1 Pallas
//! kernels) to HLO *text* under `artifacts/`; this module loads each one via
//! `HloModuleProto::from_text_file`, compiles it on the PJRT CPU client, and
//! serves `execute(name, args)` calls.  Python never runs here.
//!
//! The `xla` crate's handles are not `Send`/`Sync` (raw PJRT pointers), so
//! the registry lives on a dedicated **runtime service thread** — a faithful
//! model of a single accelerator device with a submission queue.  Callers
//! (worker threads, worker processes) hold a cheap cloneable [`RuntimeHandle`]
//! and exchange [`Value`]s over channels; Value↔Literal conversion happens
//! on the service thread.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::Mutex;

use once_cell::sync::OnceCell;

use crate::api::error::{EvalError, FutureError};
use crate::api::value::{Tensor, Value};
use crate::util::json::{self, Json};

/// Manifest entry for one compiled kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelSpec {
    pub name: String,
    pub file: String,
    pub arg_shapes: Vec<Vec<usize>>,
    pub out_shapes: Vec<Vec<usize>>,
}

/// Parse `artifacts/manifest.json` (written by `python/compile/aot.py`).
pub fn parse_manifest(text: &str) -> Result<Vec<KernelSpec>, FutureError> {
    let doc = json::parse(text).map_err(|e| FutureError::Runtime(format!("manifest: {e}")))?;
    let entries = doc
        .get("entries")
        .and_then(Json::as_arr)
        .ok_or_else(|| FutureError::Runtime("manifest: missing 'entries'".into()))?;
    let mut specs = Vec::with_capacity(entries.len());
    for e in entries {
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| FutureError::Runtime("manifest entry: missing 'name'".into()))?;
        let file = e
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| FutureError::Runtime("manifest entry: missing 'file'".into()))?;
        let shapes = |key: &str| -> Result<Vec<Vec<usize>>, FutureError> {
            e.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| FutureError::Runtime(format!("manifest entry: missing '{key}'")))?
                .iter()
                .map(|a| {
                    a.get("shape")
                        .and_then(Json::as_arr)
                        .map(|dims| dims.iter().filter_map(Json::as_i64).map(|d| d as usize).collect())
                        .ok_or_else(|| FutureError::Runtime("manifest arg: missing 'shape'".into()))
                })
                .collect()
        };
        specs.push(KernelSpec {
            name: name.to_string(),
            file: file.to_string(),
            arg_shapes: shapes("args")?,
            out_shapes: shapes("outputs")?,
        });
    }
    Ok(specs)
}

/// The registry proper — only ever touched by the service thread.
///
/// Artifacts are parsed from the manifest eagerly (cheap) but each HLO
/// module is loaded + compiled **lazily on first call** (§Perf: a worker
/// that only runs `slow_fcn` must not pay for compiling the other four
/// entries; this cut first-call latency ~6× — 1.0s → 0.17s).
struct KernelRegistry {
    dir: std::path::PathBuf,
    client: xla::PjRtClient,
    specs: HashMap<String, KernelSpec>,
    compiled: std::cell::RefCell<HashMap<String, xla::PjRtLoadedExecutable>>,
}

impl KernelRegistry {
    fn load(dir: &Path) -> Result<Self, FutureError> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            FutureError::Runtime(format!("cannot read {}: {e}", manifest_path.display()))
        })?;
        let specs = parse_manifest(&text)?
            .into_iter()
            .map(|s| (s.name.clone(), s))
            .collect();
        let client = xla::PjRtClient::cpu()
            .map_err(|e| FutureError::Runtime(format!("PJRT client: {e}")))?;
        Ok(KernelRegistry {
            dir: dir.to_path_buf(),
            client,
            specs,
            compiled: std::cell::RefCell::new(HashMap::new()),
        })
    }

    /// Compile `name` if not yet cached.
    fn ensure_compiled(&self, name: &str, spec: &KernelSpec) -> Result<(), EvalError> {
        if self.compiled.borrow().contains_key(name) {
            return Ok(());
        }
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path)
            .map_err(|e| EvalError::new(format!("load {}: {e}", path.display())))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| EvalError::new(format!("compile {name}: {e}")))?;
        self.compiled.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    fn execute(&self, name: &str, args: &[Value]) -> Result<Value, EvalError> {
        let spec = self.specs.get(name).ok_or_else(|| {
            EvalError::new(format!(
                "could not find function \"{name}\" (not in artifact manifest)"
            ))
        })?;
        self.ensure_compiled(name, spec)?;
        let compiled = self.compiled.borrow();
        let exe = compiled.get(name).expect("just compiled");
        if args.len() != spec.arg_shapes.len() {
            return Err(EvalError::new(format!(
                "{name}: expected {} arguments, got {}",
                spec.arg_shapes.len(),
                args.len()
            )));
        }
        let mut literals = Vec::with_capacity(args.len());
        for (i, (arg, want)) in args.iter().zip(&spec.arg_shapes).enumerate() {
            let t = arg.as_tensor().ok_or_else(|| {
                EvalError::new(format!(
                    "{name}: argument {i} must be a tensor, got {}",
                    arg.type_name()
                ))
            })?;
            if &t.shape != want {
                return Err(EvalError::new(format!(
                    "{name}: argument {i} has shape {:?}, expected {:?}",
                    t.shape, want
                )));
            }
            let dims: Vec<i64> = t.shape.iter().map(|d| *d as i64).collect();
            let lit = xla::Literal::vec1(&t.data)
                .reshape(&dims)
                .map_err(|e| EvalError::new(format!("{name}: arg {i} reshape: {e}")))?;
            literals.push(lit);
        }
        let result = exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| EvalError::new(format!("{name}: execute: {e}")))?;
        let root = result[0][0]
            .to_literal_sync()
            .map_err(|e| EvalError::new(format!("{name}: device→host: {e}")))?;
        // aot.py lowers with return_tuple=True: the root literal is a tuple.
        let parts = root
            .to_tuple()
            .map_err(|e| EvalError::new(format!("{name}: untuple: {e}")))?;
        let mut out = Vec::with_capacity(parts.len());
        for (i, part) in parts.into_iter().enumerate() {
            let shape = spec.out_shapes.get(i).cloned().unwrap_or_default();
            let data = part
                .to_vec::<f32>()
                .map_err(|e| EvalError::new(format!("{name}: output {i} to_vec: {e}")))?;
            let tensor = Tensor::new(shape, data)
                .map_err(|m| EvalError::new(format!("{name}: output {i}: {m}")))?;
            out.push(Value::Tensor(tensor));
        }
        Ok(if out.len() == 1 { out.pop().unwrap() } else { Value::List(out) })
    }

    fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.specs.keys().cloned().collect();
        names.sort();
        names
    }
}

enum Request {
    Execute { name: String, args: Vec<Value>, reply: mpsc::Sender<Result<Value, EvalError>> },
    Names { reply: mpsc::Sender<Vec<String>> },
}

/// Cheap, thread-safe handle to the runtime service thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::Sender<Request>,
}

// mpsc::Sender<Request> is Send but not Sync; guard it for the global.
pub struct SharedRuntime {
    tx: Mutex<mpsc::Sender<Request>>,
}

impl SharedRuntime {
    /// A fresh per-caller handle.
    pub fn handle(&self) -> RuntimeHandle {
        RuntimeHandle { tx: self.tx.lock().unwrap().clone() }
    }
}

impl RuntimeHandle {
    /// Execute kernel `name` on the device thread, blocking for the result.
    pub fn execute(&self, name: &str, args: Vec<Value>) -> Result<Value, EvalError> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request::Execute { name: name.to_string(), args, reply: reply_tx })
            .map_err(|_| EvalError::new(format!("{name}: runtime thread is gone")))?;
        reply_rx
            .recv()
            .map_err(|_| EvalError::new(format!("{name}: runtime thread dropped reply")))?
    }

    /// Names of all loaded kernels.
    pub fn kernel_names(&self) -> Vec<String> {
        let (reply_tx, reply_rx) = mpsc::channel();
        if self.tx.send(Request::Names { reply: reply_tx }).is_err() {
            return Vec::new();
        }
        reply_rx.recv().unwrap_or_default()
    }
}

/// Spawn a runtime service thread for `dir`.  Fails fast if the manifest is
/// missing or any artifact does not compile.
pub fn spawn_runtime(dir: PathBuf) -> Result<SharedRuntime, FutureError> {
    let (tx, rx) = mpsc::channel::<Request>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<(), FutureError>>();
    std::thread::Builder::new()
        .name("rustures-pjrt".into())
        .spawn(move || {
            let registry = match KernelRegistry::load(&dir) {
                Ok(r) => {
                    let _ = ready_tx.send(Ok(()));
                    r
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Execute { name, args, reply } => {
                        let _ = reply.send(registry.execute(&name, &args));
                    }
                    Request::Names { reply } => {
                        let _ = reply.send(registry.names());
                    }
                }
            }
        })
        .map_err(|e| FutureError::Runtime(format!("spawn runtime thread: {e}")))?;
    ready_rx
        .recv()
        .map_err(|_| FutureError::Runtime("runtime thread died during load".into()))??;
    Ok(SharedRuntime { tx: Mutex::new(tx) })
}

/// Artifact directory: `$RUSTURES_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("RUSTURES_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from("artifacts")
    })
}

static GLOBAL: OnceCell<Option<SharedRuntime>> = OnceCell::new();

/// Process-global runtime, lazily spawned from [`artifacts_dir`].
/// `None` when artifacts are absent (pure-coordination tests still work;
/// kernel calls then fail with an eval error).
pub fn global() -> Option<&'static SharedRuntime> {
    GLOBAL
        .get_or_init(|| {
            let dir = artifacts_dir();
            if !dir.join("manifest.json").exists() {
                return None;
            }
            match spawn_runtime(dir) {
                Ok(rt) => Some(rt),
                Err(e) => {
                    eprintln!("rustures: failed to load PJRT runtime: {e}");
                    None
                }
            }
        })
        .as_ref()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_extracts_specs() {
        let text = r#"{"format":1,"entries":[
            {"name":"f","file":"f.hlo.txt",
             "args":[{"shape":[2,2],"dtype":"float32"}],
             "outputs":[{"shape":[],"dtype":"float32"}],"sha256":"x"}]}"#;
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].name, "f");
        assert_eq!(specs[0].arg_shapes, vec![vec![2, 2]]);
        assert_eq!(specs[0].out_shapes, vec![Vec::<usize>::new()]);
    }

    #[test]
    fn parse_manifest_rejects_malformed() {
        assert!(parse_manifest("{}").is_err());
        assert!(parse_manifest("not json").is_err());
        assert!(parse_manifest(r#"{"entries":[{"file":"x"}]}"#).is_err());
    }
}
