//! PJRT runtime: load AOT artifacts and execute them from the request path.
//!
//! `make artifacts` lowers the L2 JAX graphs (which call the L1 Pallas
//! kernels) to HLO *text* under `artifacts/`; this module loads each one via
//! the manifest, compiles it on the PJRT CPU client, and serves
//! `execute(name, args)` calls.  Python never runs here.
//!
//! PJRT handles are not `Send`/`Sync` (raw device pointers), so the registry
//! lives on a dedicated **runtime service thread** — a faithful model of a
//! single accelerator device with a submission queue.  Callers (worker
//! threads, worker processes) hold a cheap cloneable [`RuntimeHandle`] and
//! exchange [`Value`]s over channels; Value↔device-buffer conversion happens
//! on the service thread.
//!
//! ## Offline stub
//!
//! The `xla` crate that provides the actual PJRT binding is not vendored in
//! this image, so the default build compiles a **stub device**: manifest
//! parsing, argument validation (arity, shapes, tensor-ness), and the
//! service-thread plumbing are all real, but execution returns a clean
//! [`EvalError`].  Because no `artifacts/manifest.json` ships with the repo,
//! [`global`] returns `None` in practice and the kernel integration tests
//! skip — exactly the pre-existing "artifacts absent" path.  Restoring real
//! execution = vendor `xla`, enable the `pjrt` cargo feature, and implement
//! [`Device::execute`] over it.

// The feature exists so downstream build scripts can express intent, but
// turning it on without vendoring the binding would silently keep the stub —
// fail the build loudly instead.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires vendoring the `xla` crate and restoring the \
     real PJRT device in src/runtime/mod.rs (see the module docs); the default \
     build uses the stub runtime"
);

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::mpsc;
use std::sync::{Mutex, OnceLock};

use crate::api::error::{EvalError, FutureError};
use crate::api::value::Value;
use crate::util::json::{self, Json};

/// Manifest entry for one compiled kernel.
#[derive(Debug, Clone, PartialEq)]
pub struct KernelSpec {
    pub name: String,
    pub file: String,
    pub arg_shapes: Vec<Vec<usize>>,
    pub out_shapes: Vec<Vec<usize>>,
}

/// Parse `artifacts/manifest.json` (written by `python/compile/aot.py`).
pub fn parse_manifest(text: &str) -> Result<Vec<KernelSpec>, FutureError> {
    let doc = json::parse(text).map_err(|e| FutureError::Runtime(format!("manifest: {e}")))?;
    let entries = doc
        .get("entries")
        .and_then(Json::as_arr)
        .ok_or_else(|| FutureError::Runtime("manifest: missing 'entries'".into()))?;
    let mut specs = Vec::with_capacity(entries.len());
    for e in entries {
        let name = e
            .get("name")
            .and_then(Json::as_str)
            .ok_or_else(|| FutureError::Runtime("manifest entry: missing 'name'".into()))?;
        let file = e
            .get("file")
            .and_then(Json::as_str)
            .ok_or_else(|| FutureError::Runtime("manifest entry: missing 'file'".into()))?;
        let shapes = |key: &str| -> Result<Vec<Vec<usize>>, FutureError> {
            e.get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| FutureError::Runtime(format!("manifest entry: missing '{key}'")))?
                .iter()
                .map(|a| {
                    a.get("shape")
                        .and_then(Json::as_arr)
                        .map(|dims| {
                            dims.iter().filter_map(Json::as_i64).map(|d| d as usize).collect()
                        })
                        .ok_or_else(|| FutureError::Runtime("manifest arg: missing 'shape'".into()))
                })
                .collect()
        };
        specs.push(KernelSpec {
            name: name.to_string(),
            file: file.to_string(),
            arg_shapes: shapes("args")?,
            out_shapes: shapes("outputs")?,
        });
    }
    Ok(specs)
}

/// The execution device behind the registry.  The stub validates that the
/// artifact file exists and then reports the missing binding; a real PJRT
/// device (feature `pjrt` + vendored `xla` crate) compiles the HLO text and
/// runs it.
trait Device {
    fn execute(
        &self,
        spec: &KernelSpec,
        artifact_path: &Path,
        args: &[Value],
    ) -> Result<Value, EvalError>;
}

/// Offline stand-in for the PJRT CPU client.
struct StubDevice;

impl Device for StubDevice {
    fn execute(
        &self,
        spec: &KernelSpec,
        artifact_path: &Path,
        _args: &[Value],
    ) -> Result<Value, EvalError> {
        if !artifact_path.exists() {
            return Err(EvalError::new(format!(
                "load {}: artifact file missing",
                artifact_path.display()
            )));
        }
        Err(EvalError::new(format!(
            "{}: PJRT execution unavailable in this build (stub runtime; vendor the `xla` \
             crate and enable the `pjrt` feature)",
            spec.name
        )))
    }
}

/// The registry proper — only ever touched by the service thread.
struct KernelRegistry {
    dir: PathBuf,
    specs: HashMap<String, KernelSpec>,
    device: Box<dyn Device>,
}

impl KernelRegistry {
    fn load(dir: &Path) -> Result<Self, FutureError> {
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            FutureError::Runtime(format!("cannot read {}: {e}", manifest_path.display()))
        })?;
        let specs = parse_manifest(&text)?
            .into_iter()
            .map(|s| (s.name.clone(), s))
            .collect();
        Ok(KernelRegistry { dir: dir.to_path_buf(), specs, device: Box::new(StubDevice) })
    }

    /// Validate and dispatch one kernel call.  Validation (arity, tensor
    /// args, shape agreement) is device-independent and fully exercised by
    /// the stub build.
    fn execute(&self, name: &str, args: &[Value]) -> Result<Value, EvalError> {
        let spec = self.specs.get(name).ok_or_else(|| {
            EvalError::new(format!(
                "could not find function \"{name}\" (not in artifact manifest)"
            ))
        })?;
        if args.len() != spec.arg_shapes.len() {
            return Err(EvalError::new(format!(
                "{name}: expected {} arguments, got {}",
                spec.arg_shapes.len(),
                args.len()
            )));
        }
        for (i, (arg, want)) in args.iter().zip(&spec.arg_shapes).enumerate() {
            let t = arg.as_tensor().ok_or_else(|| {
                EvalError::new(format!(
                    "{name}: argument {i} must be a tensor, got {}",
                    arg.type_name()
                ))
            })?;
            if &t.shape != want {
                return Err(EvalError::new(format!(
                    "{name}: argument {i} has shape {:?}, expected {:?}",
                    t.shape, want
                )));
            }
        }
        self.device.execute(spec, &self.dir.join(&spec.file), args)
    }

    fn names(&self) -> Vec<String> {
        let mut names: Vec<String> = self.specs.keys().cloned().collect();
        names.sort();
        names
    }
}

enum Request {
    Execute { name: String, args: Vec<Value>, reply: mpsc::Sender<Result<Value, EvalError>> },
    Names { reply: mpsc::Sender<Vec<String>> },
}

/// Cheap, thread-safe handle to the runtime service thread.
#[derive(Clone)]
pub struct RuntimeHandle {
    tx: mpsc::Sender<Request>,
}

// mpsc::Sender<Request> is Send but not Sync; guard it for the global.
pub struct SharedRuntime {
    tx: Mutex<mpsc::Sender<Request>>,
}

impl SharedRuntime {
    /// A fresh per-caller handle.
    pub fn handle(&self) -> RuntimeHandle {
        RuntimeHandle { tx: self.tx.lock().unwrap().clone() }
    }
}

impl RuntimeHandle {
    /// Execute kernel `name` on the device thread, blocking for the result.
    pub fn execute(&self, name: &str, args: Vec<Value>) -> Result<Value, EvalError> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Request::Execute { name: name.to_string(), args, reply: reply_tx })
            .map_err(|_| EvalError::new(format!("{name}: runtime thread is gone")))?;
        reply_rx
            .recv()
            .map_err(|_| EvalError::new(format!("{name}: runtime thread dropped reply")))?
    }

    /// Names of all loaded kernels.
    pub fn kernel_names(&self) -> Vec<String> {
        let (reply_tx, reply_rx) = mpsc::channel();
        if self.tx.send(Request::Names { reply: reply_tx }).is_err() {
            return Vec::new();
        }
        reply_rx.recv().unwrap_or_default()
    }
}

/// Spawn a runtime service thread for `dir`.  Fails fast if the manifest is
/// missing or malformed.
pub fn spawn_runtime(dir: PathBuf) -> Result<SharedRuntime, FutureError> {
    let (tx, rx) = mpsc::channel::<Request>();
    let (ready_tx, ready_rx) = mpsc::channel::<Result<(), FutureError>>();
    std::thread::Builder::new()
        .name("rustures-pjrt".into())
        .spawn(move || {
            let registry = match KernelRegistry::load(&dir) {
                Ok(r) => {
                    let _ = ready_tx.send(Ok(()));
                    r
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            while let Ok(req) = rx.recv() {
                match req {
                    Request::Execute { name, args, reply } => {
                        let _ = reply.send(registry.execute(&name, &args));
                    }
                    Request::Names { reply } => {
                        let _ = reply.send(registry.names());
                    }
                }
            }
        })
        .map_err(|e| FutureError::Runtime(format!("spawn runtime thread: {e}")))?;
    ready_rx
        .recv()
        .map_err(|_| FutureError::Runtime("runtime thread died during load".into()))??;
    Ok(SharedRuntime { tx: Mutex::new(tx) })
}

/// Artifact directory: `$RUSTURES_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("RUSTURES_ARTIFACTS").map(PathBuf::from).unwrap_or_else(|_| {
        PathBuf::from("artifacts")
    })
}

static GLOBAL: OnceLock<Option<SharedRuntime>> = OnceLock::new();

/// Process-global runtime, lazily spawned from [`artifacts_dir`].
/// `None` when artifacts are absent (pure-coordination tests still work;
/// kernel calls then fail with an eval error).
///
/// In the stub build (no vendored `xla`), this is `None` even when
/// artifacts exist: execution would fail on every call, so kernel tests
/// and examples take their documented skip path instead of hard-failing.
pub fn global() -> Option<&'static SharedRuntime> {
    GLOBAL
        .get_or_init(|| {
            let dir = artifacts_dir();
            if !dir.join("manifest.json").exists() {
                return None;
            }
            // Load through the real path so a corrupt manifest is
            // diagnosed fail-fast even in the stub build...
            match spawn_runtime(dir.clone()) {
                Ok(rt) => {
                    // ...but decline to SERVE execution while the device is
                    // the stub: every call would fail, so kernel tests and
                    // examples take their documented skip path instead.
                    // When the real PJRT binding is restored, return
                    // `Some(rt)` here.
                    drop(rt);
                    eprintln!(
                        "rustures: artifacts found at {} but this build carries the \
                         stub PJRT runtime (vendor the `xla` crate and restore the \
                         binding to execute kernels); continuing without a runtime",
                        dir.display()
                    );
                    None
                }
                Err(e) => {
                    eprintln!("rustures: failed to load PJRT runtime: {e}");
                    None
                }
            }
        })
        .as_ref()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::value::Tensor;

    #[test]
    fn parse_manifest_extracts_specs() {
        let text = r#"{"format":1,"entries":[
            {"name":"f","file":"f.hlo.txt",
             "args":[{"shape":[2,2],"dtype":"float32"}],
             "outputs":[{"shape":[],"dtype":"float32"}],"sha256":"x"}]}"#;
        let specs = parse_manifest(text).unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].name, "f");
        assert_eq!(specs[0].arg_shapes, vec![vec![2, 2]]);
        assert_eq!(specs[0].out_shapes, vec![Vec::<usize>::new()]);
    }

    #[test]
    fn parse_manifest_rejects_malformed() {
        assert!(parse_manifest("{}").is_err());
        assert!(parse_manifest("not json").is_err());
        assert!(parse_manifest(r#"{"entries":[{"file":"x"}]}"#).is_err());
    }

    #[test]
    fn registry_validates_before_dispatch() {
        // Arg validation runs device-independently (stub or real PJRT).
        let spec = KernelSpec {
            name: "f".into(),
            file: "f.hlo.txt".into(),
            arg_shapes: vec![vec![2, 2]],
            out_shapes: vec![vec![]],
        };
        let registry = KernelRegistry {
            dir: PathBuf::from("/nonexistent"),
            specs: [("f".to_string(), spec)].into_iter().collect(),
            device: Box::new(StubDevice),
        };
        let err = registry.execute("nope", &[]).unwrap_err();
        assert!(err.message.contains("could not find function"));
        let err = registry.execute("f", &[]).unwrap_err();
        assert!(err.message.contains("expected 1 arguments"));
        let err = registry.execute("f", &[Value::I64(1)]).unwrap_err();
        assert!(err.message.contains("must be a tensor"));
        let bad = Value::Tensor(Tensor::zeros(&[3]));
        let err = registry.execute("f", &[bad]).unwrap_err();
        assert!(err.message.contains("shape"));
        // Valid args reach the device, which reports the missing artifact.
        let ok_arg = Value::Tensor(Tensor::zeros(&[2, 2]));
        let err = registry.execute("f", &[ok_arg]).unwrap_err();
        assert!(err.message.contains("artifact file missing"), "{}", err.message);
    }
}
