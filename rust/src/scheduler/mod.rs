//! A simulated HPC job scheduler — the substrate under the `batchtools`
//! backend (Slurm/SGE/Torque in the paper; none exist in this image, so we
//! build the closest synthetic equivalent; see DESIGN.md §Substitutions).
//!
//! Faithful to the batch model the paper leans on:
//!
//! * **file-staged jobs** — tasks are spooled to disk, results come back as
//!   files (no live channel: immediates cannot relay early, exactly like
//!   `future.batchtools`);
//! * **submission latency** — a configurable delay between `submit` and a
//!   job becoming eligible (the scheduler's queue overhead);
//! * **nodes × slots** — a daemon admits pending jobs to free slots in
//!   submission order, runs each as an isolated worker process
//!   (`rustures worker --batch-job ...`), and harvests exit codes;
//! * **polling** — clients learn about completion by polling job state,
//!   never by callback.

use std::collections::{HashMap, VecDeque};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::api::error::FutureError;
use crate::backend::dispatch::CompletionWaker;
use crate::capacity::{BreakerConfig, PoolRegistration, RevivePolicy, SlotLease};
use crate::util::exe::worker_exe;
use crate::util::uuid_v4;

/// Chaos hook (the `!noconnect` family, aimed at the scheduler itself):
/// when armed, the daemon exits at the top of its next tick — simulating a
/// crashed scheduler daemon, not just a crashed job process.  The daemon's
/// exit guard then surfaces structured failures to every waiting handle
/// (queued futures error instead of hanging).  Self-disarming (fires once).
static CHAOS_DAEMONDIE: AtomicBool = AtomicBool::new(false);

/// Arm the daemon-death chaos probe for the next daemon tick.
pub fn arm_chaos_daemondie() {
    CHAOS_DAEMONDIE.store(true, Ordering::SeqCst);
}

/// Job identifier (scheduler-scoped).
pub type JobId = u64;

/// Lifecycle of a submitted job.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum JobState {
    /// Accepted, waiting for the submission latency and a free slot.
    Pending,
    /// Executing on a node slot.
    Running { node: usize },
    /// Worker exited 0 and the result file exists.
    Completed,
    /// Worker crashed / nonzero exit / lost.
    Failed(String),
    /// Cancelled before completion.
    Cancelled,
}

/// Scheduler configuration.
#[derive(Debug, Clone)]
pub struct SchedConfig {
    /// Number of nodes (each node = one worker process at a time here;
    /// `slots_per_node` generalizes).
    pub nodes: usize,
    pub slots_per_node: usize,
    /// Simulated queueing delay before a submitted job may start.
    pub submit_latency: Duration,
    /// Daemon tick.
    pub tick: Duration,
    /// Spool directory for task/result files.
    pub spool: PathBuf,
}

impl SchedConfig {
    pub fn local(nodes: usize) -> Self {
        SchedConfig {
            nodes: nodes.max(1),
            slots_per_node: 1,
            submit_latency: Duration::from_millis(5),
            tick: Duration::from_millis(2),
            spool: std::env::temp_dir().join(format!("rustures-sched-{}", uuid_v4())),
        }
    }

    pub fn total_slots(&self) -> usize {
        self.nodes * self.slots_per_node
    }
}

struct Job {
    id: JobId,
    task_file: PathBuf,
    result_file: PathBuf,
    state: JobState,
    submitted_at: Instant,
    child: Option<Child>,
    node: Option<usize>,
    /// Originating session (quota key for the ledger admission).
    session: u64,
    /// Attempt epoch the submitter launched this job under.  The daemon
    /// fences any harvested result frame echoing a different epoch — a
    /// late write from a superseded attempt must never surface as this
    /// job's value.
    expected_attempt: u32,
    /// The node-slot lease held while the job runs; dropped (slot freed)
    /// on the terminal transition — capacity frees when a job *completes*,
    /// not when its result is collected.
    lease: Option<SlotLease>,
}

struct SchedState {
    queue: VecDeque<JobId>,
    jobs: HashMap<JobId, Job>,
    free_slots: Vec<usize>, // node indices with capacity
    /// job id → completion subscription, notified once by the daemon when
    /// the job reaches a terminal state.  This is the ONE exception to the
    /// "clients learn by polling" rule above: in-process clients (the batch
    /// backend's handles) may register a waker so `resolve()` does not have
    /// to poll N jobs — the file-staged protocol itself is unchanged.
    waiters: HashMap<JobId, (Arc<CompletionWaker>, u64)>,
}

impl SchedState {
    fn notify_job_waiter(&mut self, id: JobId) {
        if let Some((waker, token)) = self.waiters.remove(&id) {
            waker.notify(token);
        }
    }
}

/// The scheduler daemon + client API.
pub struct Scheduler {
    config: SchedConfig,
    state: Arc<Mutex<SchedState>>,
    next_id: AtomicU64,
    stop: Arc<AtomicBool>,
    daemon: Mutex<Option<JoinHandle<()>>>,
    /// Node slots as capacity-ledger seats: the daemon acquires one lease
    /// per admitted job (session quotas apply there) and releases it on
    /// the job's terminal transition.
    reg: Arc<PoolRegistration>,
    /// False the moment the daemon thread exits — however it exits.
    /// Handles consult this so a dead daemon surfaces as a structured
    /// error instead of an eternal `Pending` poll.
    daemon_alive: Arc<AtomicBool>,
}

impl Scheduler {
    /// Start the daemon.
    pub fn start(config: SchedConfig) -> Result<Arc<Self>, FutureError> {
        std::fs::create_dir_all(&config.spool).map_err(|e| {
            FutureError::Launch(format!("spool {}: {e}", config.spool.display()))
        })?;
        let mut free_slots = Vec::new();
        for node in 0..config.nodes {
            for _ in 0..config.slots_per_node {
                free_slots.push(node);
            }
        }
        let state = Arc::new(Mutex::new(SchedState {
            queue: VecDeque::new(),
            jobs: HashMap::new(),
            free_slots,
            waiters: HashMap::new(),
        }));
        let stop = Arc::new(AtomicBool::new(false));
        // Node slots never die (jobs are disposable; the node survives a
        // crashed job), so the seats are registered revive-less and simply
        // cycle lease → release per admitted job.
        let reg = Arc::new(PoolRegistration::register(
            "batchtools",
            &[("batch".to_string(), config.total_slots())],
            RevivePolicy::Never,
            BreakerConfig::default(),
        ));
        for _ in 0..config.total_slots() {
            reg.activate("batch");
        }
        let daemon_alive = Arc::new(AtomicBool::new(true));
        let sched = Arc::new(Scheduler {
            config: config.clone(),
            state: Arc::clone(&state),
            next_id: AtomicU64::new(1),
            stop: Arc::clone(&stop),
            daemon: Mutex::new(None),
            reg: Arc::clone(&reg),
            daemon_alive: Arc::clone(&daemon_alive),
        });

        let daemon_state = Arc::clone(&state);
        let daemon_stop = Arc::clone(&stop);
        let daemon_cfg = config;
        // Capture the constructing session's metrics sink: job crashes the
        // daemon harvests attribute to the session that owns this backend.
        let daemon_scope = crate::metrics::ambient_scope();
        let handle = std::thread::Builder::new()
            .name("rustures-sched".into())
            .spawn(move || {
                // The guard fires HOWEVER the daemon exits (orderly stop,
                // chaos kill, panic): it marks the daemon dead, releases
                // job leases, and wakes every subscriber so no future ever
                // hangs on a scheduler that stopped scheduling.
                let _guard = DaemonGuard { state: Arc::clone(&daemon_state), alive: daemon_alive };
                daemon_loop(daemon_cfg, daemon_state, daemon_stop, daemon_scope, reg)
            })
            .map_err(|e| FutureError::Launch(format!("spawn scheduler daemon: {e}")))?;
        *sched.daemon.lock().unwrap() = Some(handle);
        Ok(sched)
    }

    /// Submit a spooled task file; returns immediately with the job id
    /// (fire-and-forget, like `sbatch`).  Attributed to the default
    /// session; see [`Scheduler::submit_for_session`].
    pub fn submit(&self, task_file: PathBuf) -> JobId {
        self.submit_for_session(task_file, 0)
    }

    /// [`Scheduler::submit`] attributed to an originating session: the
    /// daemon's admission step charges the job's node-slot lease to this
    /// session, so per-session `max_workers` quotas hold across the batch
    /// backend too (a quota-capped job stays queued — FIFO — never drops).
    pub fn submit_for_session(&self, task_file: PathBuf, session: u64) -> JobId {
        self.submit_attempt(task_file, session, 0)
    }

    /// [`Scheduler::submit_for_session`] carrying the submitter's attempt
    /// epoch, which the daemon checks against the harvested result frame
    /// (stale-result fencing).
    pub fn submit_attempt(&self, task_file: PathBuf, session: u64, attempt: u32) -> JobId {
        let id = self.next_id.fetch_add(1, Ordering::SeqCst);
        let result_file = self.config.spool.join(format!("job-{id}.result"));
        let job = Job {
            id,
            task_file,
            result_file,
            state: JobState::Pending,
            submitted_at: Instant::now(),
            child: None,
            node: None,
            session,
            expected_attempt: attempt,
            lease: None,
        };
        let mut state = self.state.lock().unwrap();
        state.jobs.insert(id, job);
        state.queue.push_back(id);
        id
    }

    /// Is the scheduler daemon still running?  A dead daemon can never
    /// complete a job: handles surface structured errors instead of
    /// polling a frozen `Pending` forever.
    pub fn daemon_alive(&self) -> bool {
        self.daemon_alive.load(Ordering::SeqCst)
    }

    /// Current job state (`squeue`-style polling).
    pub fn poll(&self, id: JobId) -> Option<JobState> {
        self.state.lock().unwrap().jobs.get(&id).map(|j| j.state.clone())
    }

    /// Result file path for a completed job.
    pub fn result_file(&self, id: JobId) -> Option<PathBuf> {
        self.state.lock().unwrap().jobs.get(&id).map(|j| j.result_file.clone())
    }

    /// `scancel`: kill a pending or running job.
    pub fn cancel(&self, id: JobId) -> bool {
        let mut state = self.state.lock().unwrap();
        let Some(job) = state.jobs.get_mut(&id) else { return false };
        let cancelled = match job.state {
            JobState::Pending => {
                job.state = JobState::Cancelled;
                true
            }
            JobState::Running { .. } => {
                if let Some(child) = &mut job.child {
                    let _ = child.kill();
                }
                // The daemon harvests the kill; mark eagerly.  Terminal:
                // the node-slot lease frees now.
                job.state = JobState::Cancelled;
                job.lease.take();
                if let Some(node) = job.node.take() {
                    state.free_slots.push(node);
                }
                true
            }
            _ => false,
        };
        if cancelled {
            // Cancellation is terminal: wake resolve()-subscribers.
            state.notify_job_waiter(id);
        }
        cancelled
    }

    /// Register a completion waker for `id`: `waker.notify(token)` fires
    /// once when the job reaches a terminal state (already-terminal jobs —
    /// and unknown ids — notify immediately).
    pub fn subscribe(&self, id: JobId, waker: &Arc<CompletionWaker>, token: u64) {
        let notify_now = {
            let mut state = self.state.lock().unwrap();
            // A live job on a DEAD daemon will never transition: notify
            // now so resolve() surfaces the structured failure instead of
            // waiting forever.  (Checked under the state lock: the exit
            // guard drains waiters under the same lock, so a registration
            // racing the daemon's death is always notified by one side.)
            let live = self.daemon_alive()
                && matches!(
                    state.jobs.get(&id).map(|j| &j.state),
                    Some(JobState::Pending) | Some(JobState::Running { .. })
                );
            if live {
                state.waiters.insert(id, (Arc::clone(waker), token));
            }
            !live
        };
        if notify_now {
            waker.notify(token);
        }
    }

    /// Queue + slot occupancy snapshot: (pending, running, free slots).
    pub fn load(&self) -> (usize, usize, usize) {
        let state = self.state.lock().unwrap();
        let running = state
            .jobs
            .values()
            .filter(|j| matches!(j.state, JobState::Running { .. }))
            .count();
        (state.queue.len(), running, state.free_slots.len())
    }

    pub fn spool(&self) -> &Path {
        &self.config.spool
    }

    /// Stop the daemon and kill running jobs.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.reg.shutdown();
        if let Some(d) = self.daemon.lock().unwrap().take() {
            let _ = d.join();
        }
        let mut state = self.state.lock().unwrap();
        for job in state.jobs.values_mut() {
            if let Some(child) = &mut job.child {
                let _ = child.kill();
                let _ = child.wait();
            }
            job.lease.take();
        }
        // Jobs die with the daemon: wake every remaining subscriber.
        let waiters = std::mem::take(&mut state.waiters);
        for (_, (waker, token)) in waiters {
            waker.notify(token);
        }
        drop(state);
        let _ = std::fs::remove_dir_all(&self.config.spool);
    }
}

/// Runs when the daemon thread exits — orderly stop, chaos kill, or panic.
/// A dead daemon can never harvest or admit: mark it dead FIRST, then wake
/// every completion subscriber and release the node-slot leases of jobs
/// nobody will ever harvest, so queued futures surface structured errors
/// instead of hanging and the ledger stays truthful.
struct DaemonGuard {
    state: Arc<Mutex<SchedState>>,
    alive: Arc<AtomicBool>,
}

impl Drop for DaemonGuard {
    fn drop(&mut self) {
        self.alive.store(false, Ordering::SeqCst);
        let mut st = self.state.lock().unwrap();
        for job in st.jobs.values_mut() {
            job.lease.take();
        }
        let waiters = std::mem::take(&mut st.waiters);
        drop(st);
        for (_, (waker, token)) in waiters {
            waker.notify(token);
        }
    }
}

/// Attempt epoch echoed by the result frame on disk, or `None` when the
/// file cannot be read or decoded (the handle surfaces that as a channel
/// error; the daemon only fences frames it can positively date).
fn result_epoch(path: &PathBuf) -> Option<u32> {
    let bytes = std::fs::read(path).ok()?;
    match crate::ipc::wire::decode_message(&bytes).ok()? {
        crate::ipc::Message::Result(r) => Some(r.attempt),
        _ => None,
    }
}

fn daemon_loop(
    config: SchedConfig,
    state: Arc<Mutex<SchedState>>,
    stop: Arc<AtomicBool>,
    scope: crate::metrics::CounterScope,
    reg: Arc<PoolRegistration>,
) {
    while !stop.load(Ordering::SeqCst) {
        if CHAOS_DAEMONDIE.swap(false, Ordering::SeqCst) {
            // Chaos: the scheduler daemon itself "crashes" mid-operation.
            // No cleanup here — the exit guard is the only safety net,
            // exactly as it would be for a panic.
            return;
        }
        {
            let mut st = state.lock().unwrap();

            // 1. Harvest finished children.
            let ids: Vec<JobId> = st
                .jobs
                .values()
                .filter(|j| matches!(j.state, JobState::Running { .. }))
                .map(|j| j.id)
                .collect();
            for id in ids {
                let job = st.jobs.get_mut(&id).unwrap();
                let mut fenced = false;
                let done = match &mut job.child {
                    Some(child) => match child.try_wait() {
                        Ok(Some(status)) => Some(if status.success() && job.result_file.exists() {
                            match result_epoch(&job.result_file) {
                                Some(got) if got != job.expected_attempt => {
                                    // Stale-result fencing: a frame from a
                                    // superseded attempt epoch landed in this
                                    // job's result slot.  Drop it on the floor
                                    // so no reader can surface it; the job
                                    // fails and the supervisor relaunches.
                                    fenced = true;
                                    crate::metrics::scope_for_session(job.session).fenced();
                                    let _ = std::fs::remove_file(&job.result_file);
                                    JobState::Failed(format!(
                                        "fenced stale result (attempt {got}, expected {})",
                                        job.expected_attempt
                                    ))
                                }
                                // Unreadable frames are left for the handle to
                                // surface as a structured channel error.
                                _ => JobState::Completed,
                            }
                        } else {
                            JobState::Failed(format!("worker exit: {status}"))
                        }),
                        Ok(None) => None,
                        Err(e) => Some(JobState::Failed(format!("wait: {e}"))),
                    },
                    None => Some(JobState::Failed("no child".into())),
                };
                if let Some(new_state) = done {
                    if matches!(new_state, JobState::Failed(_)) && !fenced {
                        // A crashed/killed job process is a worker death
                        // (supervision metrics, keyed to the owning
                        // session; batch jobs are inherently disposable so
                        // there is nothing to respawn).
                        scope.worker_death();
                    }
                    job.state = new_state;
                    job.child = None;
                    // Terminal: drop the node-slot lease — capacity frees
                    // on completion, not collection.
                    job.lease.take();
                    if let Some(node) = job.node.take() {
                        st.free_slots.push(node);
                    }
                    // Terminal transition: push-notify instead of making
                    // every handle poll for it.
                    st.notify_job_waiter(id);
                }
            }

            // 2. Admit eligible pending jobs to free slots — FIFO, but a
            //    QUOTA-blocked job is skipped rather than treated as a
            //    barrier: one session at its `max_workers` cap must not
            //    starve other sessions' jobs queued behind it (per-session
            //    FIFO still holds — a session's own jobs are only ever
            //    admitted in order).
            while !st.free_slots.is_empty() {
                // Sweep cancelled/terminal entries off the queue head.
                while let Some(&front) = st.queue.front() {
                    if matches!(st.jobs[&front].state, JobState::Pending) {
                        break;
                    }
                    st.queue.pop_front();
                }
                // First admissible job: eligible (past its submission
                // latency) AND granted a ledger lease (seat free, session
                // quota not at cap).  Queue order == submission order, so
                // the first too-young job ends the scan.
                let mut admitted = None;
                for idx in 0..st.queue.len() {
                    let id = st.queue[idx];
                    let job = &st.jobs[&id];
                    if !matches!(job.state, JobState::Pending) {
                        continue; // cancelled mid-queue: swept at the head
                    }
                    if job.submitted_at.elapsed() < config.submit_latency {
                        break;
                    }
                    if let Some(lease) = reg.try_acquire(job.session) {
                        admitted = Some((idx, id, lease));
                        break;
                    }
                    // Quota-blocked: stays queued, never dropped; the jobs
                    // behind it (other sessions) get their turn.
                }
                let Some((idx, id, lease)) = admitted else { break };
                st.queue.remove(idx);
                let node = st.free_slots.pop().unwrap();
                let job = st.jobs.get_mut(&id).unwrap();
                match spawn_job_worker(&job.task_file, &job.result_file, node) {
                    Ok(child) => {
                        job.child = Some(child);
                        job.node = Some(node);
                        job.state = JobState::Running { node };
                        job.lease = Some(lease);
                    }
                    Err(e) => {
                        job.state = JobState::Failed(e.to_string());
                        st.free_slots.push(node);
                        drop(lease);
                        st.notify_job_waiter(id);
                    }
                }
            }
        }
        std::thread::sleep(config.tick);
    }
}

fn spawn_job_worker(task: &Path, result: &Path, node: usize) -> Result<Child, FutureError> {
    let exe = worker_exe()?;
    Command::new(&exe)
        .args([
            "worker",
            "--batch-job",
            &task.to_string_lossy(),
            "--out",
            &result.to_string_lossy(),
        ])
        .env("RUSTURES_NODE", node.to_string())
        .env("TF_CPP_MIN_LOG_LEVEL", "1")
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .stderr(Stdio::inherit())
        .spawn()
        .map_err(|e| FutureError::Launch(format!("spawn batch worker: {e}")))
}

#[cfg(test)]
mod tests {
    use super::*;

    // Daemon logic tests that don't need the worker binary: we submit jobs
    // whose "task files" are bogus; the child process fails fast, and the
    // scheduler must harvest the failure and recycle the slot.
    #[test]
    fn failed_jobs_release_slots() {
        if worker_exe().is_err() {
            return; // binary not built yet (unit-test-only invocation)
        }
        let sched = Scheduler::start(SchedConfig {
            submit_latency: Duration::from_millis(1),
            ..SchedConfig::local(1)
        })
        .unwrap();
        let bogus = sched.spool().join("nope.task");
        std::fs::write(&bogus, b"garbage").unwrap();
        let a = sched.submit(bogus.clone());
        let b = sched.submit(bogus);
        let deadline = Instant::now() + Duration::from_secs(15);
        loop {
            let (sa, sb) = (sched.poll(a).unwrap(), sched.poll(b).unwrap());
            let both_done = matches!(sa, JobState::Failed(_) | JobState::Completed)
                && matches!(sb, JobState::Failed(_) | JobState::Completed);
            if both_done {
                assert!(matches!(sa, JobState::Failed(_)));
                assert!(matches!(sb, JobState::Failed(_)));
                break;
            }
            assert!(Instant::now() < deadline, "scheduler wedged: {sa:?} {sb:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
        let (_, running, free) = sched.load();
        assert_eq!(running, 0);
        assert_eq!(free, 1);
        sched.shutdown();
    }

    #[test]
    fn cancel_pending_job() {
        let sched = Scheduler::start(SchedConfig {
            submit_latency: Duration::from_secs(60), // never admitted
            ..SchedConfig::local(1)
        })
        .unwrap();
        let f = sched.spool().join("x.task");
        std::fs::write(&f, b"x").unwrap();
        let id = sched.submit(f);
        assert_eq!(sched.poll(id), Some(JobState::Pending));
        assert!(sched.cancel(id));
        assert_eq!(sched.poll(id), Some(JobState::Cancelled));
        assert!(!sched.cancel(id), "double cancel is a no-op");
        sched.shutdown();
    }

    #[test]
    fn unknown_job_polls_none() {
        let sched = Scheduler::start(SchedConfig::local(1)).unwrap();
        assert_eq!(sched.poll(999), None);
        sched.shutdown();
    }
}
