//! The async multiplexed transport core: ONE poll-driven reactor thread
//! for every remote worker channel.
//!
//! Before this module, every remote worker (multisession child, cluster
//! socket) cost a dedicated blocking reader thread, and every process
//! pool ran its own stall-scan thread — a thread-per-connection design
//! that caps a cluster plan at hundreds of workers.  The reactor
//! collapses all of that onto a single poller:
//!
//! * **One thread, all channels.** Worker sockets/pipes are switched to
//!   nonblocking mode and registered with a process-wide reactor
//!   (`"rustures-poll"`), which multiplexes them through `poll(2)`
//!   (declared directly against libc — the crate stays stdlib-only).
//!   Inbound bytes accumulate in per-channel buffers and are split into
//!   frames incrementally ([`crate::ipc::frame::try_split_frame`]);
//!   each decoded [`Message`] is handed to the owning pool's handler,
//!   which feeds the existing `CompletionWaker`/`Dispatcher` plumbing.
//! * **Buffered outboxes with backpressure.** Writes never block the
//!   caller: [`ChannelHandle::send_bytes`] appends to a per-channel
//!   outbox that the reactor drains on write-readiness.  Senders that
//!   want backpressure (task launches) call
//!   [`ChannelHandle::wait_outbox_below`] — the reactor itself never
//!   does, so it can never deadlock on a queue only it can drain.
//! * **Stall deadlines as timer entries.** The per-pool `stall_loop`
//!   scan threads are gone: a channel arms a stall deadline
//!   ([`ChannelHandle::arm_stall`], fed by the per-session
//!   [`crate::liveness::LivenessConfig`]) and the reactor's poll timeout
//!   doubles as the timer wheel — expiry dispatches
//!   [`ChannelEvent::Stalled`] to the pool, which re-checks under its
//!   own lock and kills or re-arms.
//!
//! ## Fallback pump channels
//!
//! Channels without real file descriptors (in-memory test transports,
//! non-unix hosts, or everything under [`force_pump_scope`] — the legacy
//! thread-per-connection path kept for A/B conformance and benches) get
//! a dedicated `"rustures-pump"` reader thread that feeds the *same*
//! handler/event path, and still park their stall deadlines on the
//! reactor's timer scan.  Real cluster/multisession plans always take
//! the fd path, so the acceptance bar — exactly one poller thread, zero
//! per-seat reader threads — holds where it matters.
//!
//! ## Events and ordering
//!
//! Handlers run on the reactor (or pump) thread, outside every reactor
//! lock, in per-channel arrival order.  A handler may take its pool's
//! lock and may write to any channel (enqueue + wake — nonblocking), but
//! must never call [`ChannelHandle::wait_outbox_below`].

use std::collections::HashMap;
use std::io::{Read, Write};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, OnceLock};
use std::time::{Duration, Instant};

use crate::api::error::FutureError;
use crate::ipc::frame::{read_frame, try_split_frame};
use crate::ipc::{wire, Message};

// ------------------------------------------------------------- raw poll ----

#[cfg(unix)]
mod sys {
    //! Minimal libc surface for the reactor (the crate is stdlib-only, so
    //! `poll(2)`/`fcntl(2)` are declared directly; std already links libc
    //! and `std::io::Error::last_os_error()` reads `errno` portably).

    /// `struct pollfd` (identical layout on every supported unix).
    #[repr(C)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;
    pub const POLLOUT: i16 = 0x004;
    pub const POLLERR: i16 = 0x008;
    pub const POLLHUP: i16 = 0x010;

    const F_GETFL: i32 = 3;
    const F_SETFL: i32 = 4;
    #[cfg(target_os = "linux")]
    const O_NONBLOCK: i32 = 0o4000;
    #[cfg(not(target_os = "linux"))]
    const O_NONBLOCK: i32 = 0x4;

    #[cfg(target_os = "linux")]
    type NfdsT = u64;
    #[cfg(not(target_os = "linux"))]
    type NfdsT = u32;

    extern "C" {
        fn poll(fds: *mut PollFd, nfds: NfdsT, timeout: i32) -> i32;
        fn fcntl(fd: i32, cmd: i32, ...) -> i32;
        fn read(fd: i32, buf: *mut core::ffi::c_void, count: usize) -> isize;
        fn write(fd: i32, buf: *const core::ffi::c_void, count: usize) -> isize;
    }

    /// Outcome of one nonblocking read/write attempt.
    pub enum IoStep {
        /// Bytes transferred.
        Data(usize),
        /// `EAGAIN`/`EWOULDBLOCK` — try again after readiness.
        WouldBlock,
        /// End of stream (reads only).
        Eof,
        /// Hard error (the channel is dead).
        Fatal(std::io::Error),
    }

    pub fn set_nonblocking(fd: i32) -> std::io::Result<()> {
        // Safety: plain fcntl on a caller-owned descriptor.
        unsafe {
            let flags = fcntl(fd, F_GETFL);
            if flags < 0 {
                return Err(std::io::Error::last_os_error());
            }
            if fcntl(fd, F_SETFL, flags | O_NONBLOCK) < 0 {
                return Err(std::io::Error::last_os_error());
            }
        }
        Ok(())
    }

    pub fn poll_fds(fds: &mut [PollFd], timeout_ms: i32) -> i32 {
        loop {
            // Safety: fds is a valid, exclusively borrowed pollfd array.
            let n = unsafe { poll(fds.as_mut_ptr(), fds.len() as NfdsT, timeout_ms) };
            if n < 0 && std::io::Error::last_os_error().kind() == std::io::ErrorKind::Interrupted
            {
                continue;
            }
            return n;
        }
    }

    pub fn read_fd(fd: i32, buf: &mut [u8]) -> IoStep {
        loop {
            // Safety: buf is a valid, exclusively borrowed byte buffer.
            let n = unsafe { read(fd, buf.as_mut_ptr().cast(), buf.len()) };
            if n > 0 {
                return IoStep::Data(n as usize);
            }
            if n == 0 {
                return IoStep::Eof;
            }
            let err = std::io::Error::last_os_error();
            match err.kind() {
                std::io::ErrorKind::Interrupted => continue,
                std::io::ErrorKind::WouldBlock => return IoStep::WouldBlock,
                _ => return IoStep::Fatal(err),
            }
        }
    }

    pub fn write_fd(fd: i32, buf: &[u8]) -> IoStep {
        loop {
            // Safety: buf is a valid borrowed byte buffer.
            let n = unsafe { write(fd, buf.as_ptr().cast(), buf.len()) };
            if n >= 0 {
                return IoStep::Data(n as usize);
            }
            let err = std::io::Error::last_os_error();
            match err.kind() {
                std::io::ErrorKind::Interrupted => continue,
                std::io::ErrorKind::WouldBlock => return IoStep::WouldBlock,
                _ => return IoStep::Fatal(err),
            }
        }
    }
}

// ------------------------------------------------------------- counters ----

static WAKEUPS: AtomicU64 = AtomicU64::new(0);
static READY_EVENTS: AtomicU64 = AtomicU64::new(0);
static TIMER_FIRES: AtomicU64 = AtomicU64::new(0);
static FRAMES_IN: AtomicU64 = AtomicU64::new(0);
static BYTES_IN: AtomicU64 = AtomicU64::new(0);
static BYTES_OUT: AtomicU64 = AtomicU64::new(0);
static FORWARDS: AtomicU64 = AtomicU64::new(0);
static PREBINDS: AtomicU64 = AtomicU64::new(0);
static BACKPRESSURE_WAITS: AtomicU64 = AtomicU64::new(0);
static PUMP_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Record one pipelined-argument forward written to a consumer's seat
/// (called by the pools; surfaces in [`stats`] / `transport_json()`).
pub fn note_forward() {
    FORWARDS.fetch_add(1, Ordering::Relaxed);
}

/// Record one pipelined dependency that was already resolved at consumer
/// creation and was bound into the task's globals instead of forwarded.
pub fn note_prebind() {
    PREBINDS.fetch_add(1, Ordering::Relaxed);
}

/// Monotonic transport counters + current channel gauges — the data
/// behind `metrics::transport_json()` (schema `rustures.transport.v1`).
#[derive(Debug, Clone, Default)]
pub struct TransportStats {
    /// `poll(2)` returns (reactor loop iterations).
    pub wakeups: u64,
    /// Ready descriptors serviced across all wakeups.
    pub ready_events: u64,
    /// Stall-deadline timer expiries dispatched.
    pub timer_fires: u64,
    /// Frames decoded off reactor- and pump-serviced channels.
    pub frames_in: u64,
    /// Raw bytes read by the reactor (fd channels only).
    pub bytes_in: u64,
    /// Raw bytes flushed from outboxes by the reactor (fd channels only).
    pub bytes_out: u64,
    /// Pipelined-argument `Forward` frames written to consumer seats.
    pub forwards: u64,
    /// Pipelined dependencies bound at creation (already resolved).
    pub prebinds: u64,
    /// Times a sender blocked in [`ChannelHandle::wait_outbox_below`].
    pub backpressure_waits: u64,
    /// Channels currently registered (fd + pump).
    pub channels_open: usize,
    /// Channels currently on the fallback pump path.
    pub channels_pump: usize,
    /// Bytes currently queued across all outboxes.
    pub outbox_bytes: u64,
}

/// Snapshot the transport counters (cheap; never starts the reactor).
pub fn stats() -> TransportStats {
    let mut s = TransportStats {
        wakeups: WAKEUPS.load(Ordering::Relaxed),
        ready_events: READY_EVENTS.load(Ordering::Relaxed),
        timer_fires: TIMER_FIRES.load(Ordering::Relaxed),
        frames_in: FRAMES_IN.load(Ordering::Relaxed),
        bytes_in: BYTES_IN.load(Ordering::Relaxed),
        bytes_out: BYTES_OUT.load(Ordering::Relaxed),
        forwards: FORWARDS.load(Ordering::Relaxed),
        prebinds: PREBINDS.load(Ordering::Relaxed),
        backpressure_waits: BACKPRESSURE_WAITS.load(Ordering::Relaxed),
        channels_open: 0,
        channels_pump: PUMP_THREADS.load(Ordering::Relaxed),
        outbox_bytes: 0,
    };
    if let Some(r) = reactor_if_running() {
        let st = r.state.lock().unwrap();
        s.channels_open = st.len();
        s.outbox_bytes = st.values().map(|e| e.ctl.outbox_len() as u64).sum();
    }
    s
}

/// Per-channel outbox depths `(channel name, queued bytes)` for the
/// metrics surface; empty when the reactor has never started.
pub fn per_channel_outbox() -> Vec<(String, usize)> {
    let Some(r) = reactor_if_running() else { return Vec::new() };
    let st = r.state.lock().unwrap();
    let mut v: Vec<(String, usize)> =
        st.values().map(|e| (e.ctl.name.clone(), e.ctl.outbox_len())).collect();
    v.sort();
    v
}

// ------------------------------------------------------- legacy override ----

static FORCE_PUMP: AtomicUsize = AtomicUsize::new(0);

/// While held, every NEW channel registration takes the legacy
/// thread-per-connection pump path instead of the reactor — the A/B
/// baseline for the `transport-reactor` conformance check and the
/// transport bench.  Nestable; existing channels are unaffected.
pub struct ForcePumpGuard(());

impl Drop for ForcePumpGuard {
    fn drop(&mut self) {
        FORCE_PUMP.fetch_sub(1, Ordering::SeqCst);
    }
}

/// Enter the legacy-path scope (see [`ForcePumpGuard`]).
pub fn force_pump_scope() -> ForcePumpGuard {
    FORCE_PUMP.fetch_add(1, Ordering::SeqCst);
    ForcePumpGuard(())
}

fn pump_forced() -> bool {
    FORCE_PUMP.load(Ordering::SeqCst) > 0
        || std::env::var_os("RUSTURES_TRANSPORT_FORCE_PUMP").is_some()
}

// --------------------------------------------------------------- events ----

/// What a registered channel reports to its owning pool.
pub enum ChannelEvent {
    /// A decoded inbound frame.
    Message(Message),
    /// Clean EOF at a frame boundary (the worker closed its end).
    Closed,
    /// The channel died mid-frame or failed to read/write/decode.
    Error(FutureError),
    /// The armed stall deadline expired with no inbound frame.  The pool
    /// re-checks under its own lock (activity may have raced) and either
    /// kills the worker or re-arms the deadline.
    Stalled {
        /// How long the channel has been silent.
        silent_for: Duration,
    },
}

/// Per-channel event callback; runs on the reactor or pump thread.
pub type Handler = Arc<dyn Fn(ChannelEvent) + Send + Sync>;

// ------------------------------------------------------------- endpoints ----

/// One worker connection handed to [`register`]: the byte streams plus,
/// when the transport is fd-backed (TCP socket, child stdio pipes), the
/// raw descriptors that let the reactor own it.  Streams without fds
/// (in-memory test transports) fall back to a pump thread.
pub struct Endpoint {
    /// Blocking read half (retained as the fd owner in reactor mode).
    pub reader: Box<dyn Read + Send>,
    /// Blocking write half (retained as the fd owner in reactor mode).
    pub writer: Box<dyn Write + Send>,
    /// Raw fd behind `reader`, if any.
    pub read_fd: Option<i32>,
    /// Raw fd behind `writer`, if any.
    pub write_fd: Option<i32>,
}

impl Endpoint {
    /// An endpoint with no usable descriptors (pump mode).
    pub fn stream(reader: Box<dyn Read + Send>, writer: Box<dyn Write + Send>) -> Self {
        Endpoint { reader, writer, read_fd: None, write_fd: None }
    }

    /// An fd-backed endpoint (reactor mode).  The boxes stay the owners;
    /// the fds must remain valid for as long as the boxes live.
    pub fn with_fds(
        reader: Box<dyn Read + Send>,
        writer: Box<dyn Write + Send>,
        read_fd: i32,
        write_fd: i32,
    ) -> Self {
        Endpoint { reader, writer, read_fd: Some(read_fd), write_fd: Some(write_fd) }
    }
}

// ------------------------------------------------------------- channels ----

struct Outbox {
    buf: Vec<u8>,
    head: usize,
    closed: bool,
}

struct ChannelCtl {
    id: u64,
    name: String,
    outbox: Mutex<Outbox>,
    drained: Condvar,
    /// Pump-mode channels write through directly (blocking), exactly like
    /// the legacy per-seat writer; reactor channels leave this `None` and
    /// go through the outbox.
    direct_writer: Option<Mutex<Box<dyn Write + Send>>>,
    last_activity_ms: AtomicU64,
    /// 0 = stall detection disarmed.
    stall_after_ms: AtomicU64,
    stall_base_ms: AtomicU64,
    closed: AtomicBool,
}

static EPOCH: OnceLock<Instant> = OnceLock::new();

fn now_ms() -> u64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_millis() as u64
}

impl ChannelCtl {
    fn touch(&self) {
        self.last_activity_ms.store(now_ms(), Ordering::SeqCst);
    }

    fn outbox_len(&self) -> usize {
        let ob = self.outbox.lock().unwrap();
        ob.buf.len() - ob.head
    }

    /// Milliseconds until the armed stall deadline (0 = already expired);
    /// `None` when disarmed or closed.
    fn stall_ms_left(&self, now: u64) -> Option<u64> {
        let after = self.stall_after_ms.load(Ordering::SeqCst);
        if after == 0 || self.closed.load(Ordering::SeqCst) {
            return None;
        }
        let base = self
            .stall_base_ms
            .load(Ordering::SeqCst)
            .max(self.last_activity_ms.load(Ordering::SeqCst));
        Some((base + after).saturating_sub(now))
    }

    fn mark_closed(&self) {
        self.closed.store(true, Ordering::SeqCst);
        let mut ob = self.outbox.lock().unwrap();
        ob.closed = true;
        ob.buf.clear();
        ob.head = 0;
        drop(ob);
        self.drained.notify_all();
    }
}

/// Cloneable handle to a registered channel — the pool's write/arm/probe
/// surface.  Dropping handles does not close the channel; channels close
/// on EOF/error (or when their owning endpoint boxes drop with the
/// reactor entry).
#[derive(Clone)]
pub struct ChannelHandle {
    ctl: Arc<ChannelCtl>,
}

impl ChannelHandle {
    /// Queue `bytes` (one or more complete frames) for the worker.
    /// Reactor channels enqueue + wake and never block; pump channels
    /// write through (blocking), like the legacy per-seat writer.
    pub fn send_bytes(&self, bytes: &[u8]) -> Result<(), FutureError> {
        if self.ctl.closed.load(Ordering::SeqCst) {
            return Err(FutureError::Channel("channel closed".into()));
        }
        if let Some(w) = &self.ctl.direct_writer {
            let mut w = w.lock().unwrap();
            return w
                .write_all(bytes)
                .and_then(|_| w.flush())
                .map_err(|e| FutureError::Channel(format!("write failed: {e}")));
        }
        {
            let mut ob = self.ctl.outbox.lock().unwrap();
            if ob.closed {
                return Err(FutureError::Channel("channel closed".into()));
            }
            ob.buf.extend_from_slice(bytes);
        }
        if let Some(r) = reactor_if_running() {
            r.wake();
        }
        Ok(())
    }

    /// Backpressure: block until the outbox holds at most `max_bytes`
    /// (or the channel closes, or `timeout` elapses — the stall detector
    /// owns genuinely wedged workers).  Returns `false` on timeout.
    /// Never call from a reactor/pump handler.
    pub fn wait_outbox_below(&self, max_bytes: usize, timeout: Duration) -> bool {
        let deadline = Instant::now() + timeout;
        let mut ob = self.ctl.outbox.lock().unwrap();
        let mut waited = false;
        while !ob.closed && ob.buf.len() - ob.head > max_bytes {
            if !waited {
                waited = true;
                BACKPRESSURE_WAITS.fetch_add(1, Ordering::Relaxed);
            }
            let now = Instant::now();
            if now >= deadline {
                return false;
            }
            let (g, _) = self.ctl.drained.wait_timeout(ob, deadline - now).unwrap();
            ob = g;
        }
        true
    }

    /// Bytes currently queued and not yet flushed to the worker.
    pub fn outbox_depth(&self) -> usize {
        self.ctl.outbox_len()
    }

    /// Arm (or re-arm) the stall deadline: if no inbound frame arrives
    /// within `after`, the reactor dispatches [`ChannelEvent::Stalled`]
    /// once and disarms.  `None` disarms.
    pub fn arm_stall(&self, after: Option<Duration>) {
        match after {
            Some(d) => {
                self.ctl.stall_base_ms.store(now_ms(), Ordering::SeqCst);
                self.ctl
                    .stall_after_ms
                    .store((d.as_millis() as u64).max(1), Ordering::SeqCst);
                if let Some(r) = reactor_if_running() {
                    r.wake();
                }
            }
            None => self.disarm_stall(),
        }
    }

    /// Disarm the stall deadline (result harvested / seat released).
    pub fn disarm_stall(&self) {
        self.ctl.stall_after_ms.store(0, Ordering::SeqCst);
    }

    /// Has the transport observed this channel die (EOF or error)?
    pub fn is_closed(&self) -> bool {
        self.ctl.closed.load(Ordering::SeqCst)
    }

    /// Deterministically retire the channel: mark it closed (pending sends
    /// fail, queued bytes are dropped) and drop the reactor entry — which
    /// drops the endpoint's owning boxes and thereby the descriptors.
    /// Idempotent; safe from handlers (no reactor lock is held during
    /// dispatch).  No event is delivered for a close initiated here.
    pub fn close(&self) {
        self.ctl.mark_closed();
        if let Some(r) = reactor_if_running() {
            r.remove(self.ctl.id);
        }
    }

    /// A `Write` adapter over [`Self::send_bytes`] — drop-in for the
    /// legacy per-seat `Box<dyn Write + Send>` writers.
    pub fn writer(&self) -> Box<dyn Write + Send> {
        Box::new(ChannelWriter(self.clone()))
    }

    /// The diagnostic name given at registration.
    pub fn name(&self) -> &str {
        &self.ctl.name
    }
}

struct ChannelWriter(ChannelHandle);

impl Write for ChannelWriter {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0
            .send_bytes(buf)
            .map(|_| buf.len())
            .map_err(|e| std::io::Error::new(std::io::ErrorKind::BrokenPipe, format!("{e}")))
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

// -------------------------------------------------------------- reactor ----

struct Entry {
    ctl: Arc<ChannelCtl>,
    handler: Handler,
    inbox: Vec<u8>,
    /// `-1` for pump channels (timer-scan-only entries).
    rfd: i32,
    wfd: i32,
    _reader: Option<Box<dyn Read + Send>>,
    _writer: Option<Box<dyn Write + Send>>,
}

struct Reactor {
    state: Mutex<HashMap<u64, Entry>>,
    next_id: AtomicU64,
    #[cfg(unix)]
    wake_tx: Mutex<std::os::unix::net::UnixStream>,
    #[cfg(unix)]
    wake_rx: Mutex<std::os::unix::net::UnixStream>,
    #[cfg(unix)]
    wake_rfd: i32,
}

static REACTOR: OnceLock<&'static Reactor> = OnceLock::new();

fn reactor() -> &'static Reactor {
    REACTOR.get_or_init(|| {
        let r: &'static Reactor = Box::leak(Box::new(Reactor::new()));
        std::thread::Builder::new()
            .name("rustures-poll".into())
            .spawn(move || r.run())
            .expect("failed to spawn transport reactor");
        r
    })
}

fn reactor_if_running() -> Option<&'static Reactor> {
    REACTOR.get().copied()
}

impl Reactor {
    #[cfg(unix)]
    fn new() -> Self {
        use std::os::unix::io::AsRawFd;
        let (rx, tx) =
            std::os::unix::net::UnixStream::pair().expect("transport wake pipe");
        rx.set_nonblocking(true).expect("wake pipe nonblocking");
        tx.set_nonblocking(true).expect("wake pipe nonblocking");
        let wake_rfd = rx.as_raw_fd();
        Reactor {
            state: Mutex::new(HashMap::new()),
            next_id: AtomicU64::new(1),
            wake_tx: Mutex::new(tx),
            wake_rx: Mutex::new(rx),
            wake_rfd,
        }
    }

    #[cfg(not(unix))]
    fn new() -> Self {
        Reactor { state: Mutex::new(HashMap::new()), next_id: AtomicU64::new(1) }
    }

    /// Interrupt the current `poll` so the fd set / timer horizon is
    /// rebuilt (new channel, new outbox bytes, new stall deadline).
    #[cfg(unix)]
    fn wake(&self) {
        use std::io::Write as _;
        let _ = self.wake_tx.lock().unwrap().write(&[1u8]);
    }

    #[cfg(not(unix))]
    fn wake(&self) {}

    fn register_entry(&self, entry: Entry) {
        let id = entry.ctl.id;
        self.state.lock().unwrap().insert(id, entry);
        self.wake();
    }

    fn remove(&self, id: u64) {
        if let Some(e) = self.state.lock().unwrap().remove(&id) {
            e.ctl.mark_closed();
        }
        self.wake();
    }

    /// The poller: build the fd set + timer horizon, `poll`, service
    /// readiness, fire expired stall deadlines, dispatch events outside
    /// every lock.
    #[cfg(unix)]
    fn run(&self) {
        use sys::{PollFd, POLLERR, POLLHUP, POLLIN, POLLOUT};
        let mut buf = vec![0u8; 64 * 1024];
        // (channel id, service reads, service writes) per pollfd past [0].
        let mut ids: Vec<(u64, bool, bool)> = Vec::new();
        loop {
            let mut fds: Vec<PollFd> =
                vec![PollFd { fd: self.wake_rfd, events: POLLIN, revents: 0 }];
            ids.clear();
            let mut timeout: i32 = -1;
            {
                let st = self.state.lock().unwrap();
                let now = now_ms();
                for (id, e) in st.iter() {
                    if let Some(left) = e.ctl.stall_ms_left(now) {
                        let left = left.min(i32::MAX as u64) as i32;
                        timeout = if timeout < 0 { left } else { timeout.min(left) };
                    }
                    if e.rfd < 0 {
                        continue; // pump channel: timer entry only
                    }
                    let wants_write = e.ctl.outbox_len() > 0;
                    if e.wfd == e.rfd {
                        let events = if wants_write { POLLIN | POLLOUT } else { POLLIN };
                        fds.push(PollFd { fd: e.rfd, events, revents: 0 });
                        ids.push((*id, true, wants_write));
                    } else {
                        fds.push(PollFd { fd: e.rfd, events: POLLIN, revents: 0 });
                        ids.push((*id, true, false));
                        if wants_write {
                            fds.push(PollFd { fd: e.wfd, events: POLLOUT, revents: 0 });
                            ids.push((*id, false, true));
                        }
                    }
                }
            }
            let n = sys::poll_fds(&mut fds, timeout);
            WAKEUPS.fetch_add(1, Ordering::Relaxed);
            if n < 0 {
                std::thread::sleep(Duration::from_millis(1));
                continue;
            }
            if fds[0].revents != 0 {
                let mut drain = [0u8; 256];
                let mut rx = self.wake_rx.lock().unwrap();
                use std::io::Read as _;
                while matches!(rx.read(&mut drain), Ok(n) if n > 0) {}
            }
            let mut events: Vec<(Handler, ChannelEvent)> = Vec::new();
            let mut dead: Vec<u64> = Vec::new();
            {
                let mut st = self.state.lock().unwrap();
                for (i, pfd) in fds.iter().enumerate().skip(1) {
                    if pfd.revents == 0 {
                        continue;
                    }
                    READY_EVENTS.fetch_add(1, Ordering::Relaxed);
                    let (id, reads, writes) = ids[i - 1];
                    if dead.contains(&id) {
                        continue;
                    }
                    let Some(e) = st.get_mut(&id) else { continue };
                    if writes && pfd.revents & (POLLOUT | POLLERR | POLLHUP) != 0 {
                        if let Err(err) = flush_outbox(e) {
                            events.push((e.handler.clone(), ChannelEvent::Error(err)));
                            dead.push(id);
                            continue;
                        }
                    }
                    if reads && pfd.revents & (POLLIN | POLLERR | POLLHUP) != 0 {
                        service_read(e, &mut buf, &mut events, &mut dead);
                    }
                }
                let now = now_ms();
                for e in st.values_mut() {
                    if e.ctl.stall_ms_left(now) == Some(0) {
                        TIMER_FIRES.fetch_add(1, Ordering::Relaxed);
                        // Fire once; the pool re-arms if it declines to kill.
                        e.ctl.stall_after_ms.store(0, Ordering::SeqCst);
                        let silent = now
                            .saturating_sub(e.ctl.last_activity_ms.load(Ordering::SeqCst));
                        events.push((
                            e.handler.clone(),
                            ChannelEvent::Stalled { silent_for: Duration::from_millis(silent) },
                        ));
                    }
                }
                for id in &dead {
                    if let Some(e) = st.remove(id) {
                        e.ctl.mark_closed();
                    }
                }
            }
            for (h, ev) in events {
                h(ev);
            }
        }
    }

    /// Non-unix fallback: no pollable fds exist (every channel pumps), so
    /// the reactor only scans stall deadlines.
    #[cfg(not(unix))]
    fn run(&self) {
        loop {
            std::thread::sleep(Duration::from_millis(25));
            WAKEUPS.fetch_add(1, Ordering::Relaxed);
            let mut events: Vec<(Handler, ChannelEvent)> = Vec::new();
            {
                let st = self.state.lock().unwrap();
                let now = now_ms();
                for e in st.values() {
                    if e.ctl.stall_ms_left(now) == Some(0) {
                        TIMER_FIRES.fetch_add(1, Ordering::Relaxed);
                        e.ctl.stall_after_ms.store(0, Ordering::SeqCst);
                        let silent =
                            now.saturating_sub(e.ctl.last_activity_ms.load(Ordering::SeqCst));
                        events.push((
                            e.handler.clone(),
                            ChannelEvent::Stalled { silent_for: Duration::from_millis(silent) },
                        ));
                    }
                }
            }
            for (h, ev) in events {
                h(ev);
            }
        }
    }
}

/// Drain as much of the outbox as the descriptor accepts right now.
#[cfg(unix)]
fn flush_outbox(e: &mut Entry) -> Result<(), FutureError> {
    use sys::IoStep;
    let mut ob = e.ctl.outbox.lock().unwrap();
    while ob.head < ob.buf.len() {
        match sys::write_fd(e.wfd, &ob.buf[ob.head..]) {
            IoStep::Data(n) => {
                ob.head += n;
                BYTES_OUT.fetch_add(n as u64, Ordering::Relaxed);
            }
            IoStep::WouldBlock => break,
            IoStep::Eof | IoStep::Fatal(_) => {
                let err = FutureError::Channel("write failed: worker channel broke".into());
                drop(ob);
                return Err(err);
            }
        }
    }
    if ob.head == ob.buf.len() {
        ob.buf.clear();
        ob.head = 0;
    } else if ob.head > (1 << 20) {
        ob.buf.drain(..ob.head);
        ob.head = 0;
    }
    drop(ob);
    e.ctl.drained.notify_all();
    Ok(())
}

/// Read until `EAGAIN`/EOF, split complete frames off the inbox, decode
/// and queue their events; queue `Closed`/`Error` and mark the channel
/// dead when the stream ends.
#[cfg(unix)]
fn service_read(
    e: &mut Entry,
    buf: &mut [u8],
    events: &mut Vec<(Handler, ChannelEvent)>,
    dead: &mut Vec<u64>,
) {
    use sys::IoStep;
    let mut end: Option<ChannelEvent> = None;
    loop {
        match sys::read_fd(e.rfd, buf) {
            IoStep::Data(n) => {
                BYTES_IN.fetch_add(n as u64, Ordering::Relaxed);
                e.ctl.touch();
                e.inbox.extend_from_slice(&buf[..n]);
            }
            IoStep::WouldBlock => break,
            IoStep::Eof => {
                end = Some(ChannelEvent::Closed);
                break;
            }
            IoStep::Fatal(err) => {
                end = Some(ChannelEvent::Error(FutureError::Channel(format!(
                    "read failed: {err}"
                ))));
                break;
            }
        }
    }
    loop {
        match try_split_frame(&e.inbox) {
            Ok(Some((frame, consumed))) => {
                e.inbox.drain(..consumed);
                match wire::decode_frame_body(frame.kind, frame.codec, &frame.body, None) {
                    Ok(msg) => {
                        FRAMES_IN.fetch_add(1, Ordering::Relaxed);
                        events.push((e.handler.clone(), ChannelEvent::Message(msg)));
                    }
                    Err(err) => {
                        end = Some(ChannelEvent::Error(FutureError::Channel(format!(
                            "bad frame: {err}"
                        ))));
                        break;
                    }
                }
            }
            Ok(None) => break,
            Err(err) => {
                end = Some(ChannelEvent::Error(err));
                break;
            }
        }
    }
    if let Some(ev) = end {
        // EOF with a partial frame buffered is a mid-frame truncation, not
        // a clean close — classify like the blocking reader would.
        let ev = match ev {
            ChannelEvent::Closed if !e.inbox.is_empty() => ChannelEvent::Error(
                FutureError::Channel("truncated frame: connection closed mid-frame".into()),
            ),
            other => other,
        };
        events.push((e.handler.clone(), ev));
        dead.push(e.ctl.id);
    }
}

// --------------------------------------------------------- registration ----

/// Register a worker channel with the transport.  fd-backed endpoints
/// (both fds present, unix, not under [`force_pump_scope`]) are switched
/// to nonblocking mode and owned by the reactor; everything else gets a
/// legacy pump thread feeding the same handler.  Either way the stall
/// deadline lives on the reactor's timer scan.
pub fn register(name: &str, endpoint: Endpoint, handler: Handler) -> ChannelHandle {
    let r = reactor();
    let id = r.next_id.fetch_add(1, Ordering::SeqCst);
    let Endpoint { reader, writer, read_fd, write_fd } = endpoint;
    let fd_mode = fd_mode_for(read_fd, write_fd);
    let new_ctl = |direct_writer: Option<Mutex<Box<dyn Write + Send>>>| {
        Arc::new(ChannelCtl {
            id,
            name: name.to_string(),
            outbox: Mutex::new(Outbox { buf: Vec::new(), head: 0, closed: false }),
            drained: Condvar::new(),
            direct_writer,
            last_activity_ms: AtomicU64::new(now_ms()),
            stall_after_ms: AtomicU64::new(0),
            stall_base_ms: AtomicU64::new(0),
            closed: AtomicBool::new(false),
        })
    };
    if fd_mode {
        let ctl = new_ctl(None);
        let handle = ChannelHandle { ctl: ctl.clone() };
        r.register_entry(Entry {
            ctl,
            handler,
            inbox: Vec::new(),
            rfd: read_fd.unwrap_or(-1),
            wfd: write_fd.unwrap_or(-1),
            // Both boxes are retained purely as fd owners: dropping them
            // here would close the descriptors under the reactor.
            _reader: Some(reader),
            _writer: Some(writer),
        });
        handle
    } else {
        // Legacy path: blocking write-through + a pump reader thread, with
        // a timer-only reactor entry so the stall deadline still works.
        let ctl = new_ctl(Some(Mutex::new(writer)));
        let handle = ChannelHandle { ctl: ctl.clone() };
        r.register_entry(Entry {
            ctl: ctl.clone(),
            handler: handler.clone(),
            inbox: Vec::new(),
            rfd: -1,
            wfd: -1,
            _reader: None,
            _writer: None,
        });
        spawn_pump(id, reader, ctl, handler);
        handle
    }
}

#[cfg(unix)]
fn fd_mode_for(read_fd: Option<i32>, write_fd: Option<i32>) -> bool {
    if pump_forced() {
        return false;
    }
    let (Some(rfd), Some(wfd)) = (read_fd, write_fd) else {
        return false;
    };
    sys::set_nonblocking(rfd).is_ok() && sys::set_nonblocking(wfd).is_ok()
}

#[cfg(not(unix))]
fn fd_mode_for(_read_fd: Option<i32>, _write_fd: Option<i32>) -> bool {
    false
}

fn spawn_pump(id: u64, mut reader: Box<dyn Read + Send>, ctl: Arc<ChannelCtl>, handler: Handler) {
    let builder = std::thread::Builder::new().name("rustures-pump".into());
    builder
        .spawn(move || {
            PUMP_THREADS.fetch_add(1, Ordering::SeqCst);
            loop {
                if ctl.closed.load(Ordering::SeqCst) {
                    break;
                }
                match read_frame(&mut reader) {
                    Ok(None) => {
                        handler(ChannelEvent::Closed);
                        break;
                    }
                    Ok(Some(frame)) => {
                        ctl.touch();
                        match wire::decode_frame_body(frame.kind, frame.codec, &frame.body, None)
                        {
                            Ok(msg) => {
                                FRAMES_IN.fetch_add(1, Ordering::Relaxed);
                                handler(ChannelEvent::Message(msg));
                            }
                            Err(err) => {
                                handler(ChannelEvent::Error(FutureError::Channel(format!(
                                    "bad frame: {err}"
                                ))));
                                break;
                            }
                        }
                    }
                    Err(err) => {
                        handler(ChannelEvent::Error(err));
                        break;
                    }
                }
            }
            if let Some(r) = reactor_if_running() {
                r.remove(id);
            }
            PUMP_THREADS.fetch_sub(1, Ordering::SeqCst);
        })
        .expect("failed to spawn transport pump thread");
}

// ---------------------------------------------------------- thread probe ----

/// Transport-relevant thread counts for this process (Linux only; `None`
/// elsewhere) — the conformance thread-count probe behind the "exactly
/// one poller, zero per-seat readers" acceptance bar.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ThreadCounts {
    /// Reactor poller threads (`rustures-poll`); at most 1 by design.
    pub reactor: usize,
    /// Legacy per-seat reader threads (`rustures-reader*`); 0 after the
    /// transport refactor.
    pub readers: usize,
    /// Fallback pump threads (`rustures-pump`); 0 for fd-backed plans.
    pub pumps: usize,
}

/// Count live transport threads by scanning `/proc/self/task/*/comm`.
pub fn thread_counts() -> Option<ThreadCounts> {
    let dir = std::fs::read_dir("/proc/self/task").ok()?;
    let mut counts = ThreadCounts::default();
    for entry in dir.flatten() {
        let comm_path = entry.path().join("comm");
        let Ok(comm) = std::fs::read_to_string(&comm_path) else { continue };
        let comm = comm.trim();
        // comm is truncated to 15 bytes, so match on prefixes that survive
        // truncation ("rustures-reader-3" reads back as "rustures-reader").
        if comm.starts_with("rustures-poll") {
            counts.reactor += 1;
        } else if comm.starts_with("rustures-reader") {
            counts.readers += 1;
        } else if comm.starts_with("rustures-pump") {
            counts.pumps += 1;
        }
    }
    Some(counts)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    /// A reader that yields `frames` then EOF.
    struct Scripted {
        data: std::io::Cursor<Vec<u8>>,
    }

    impl Read for Scripted {
        fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
            self.data.read(buf)
        }
    }

    #[test]
    fn pump_channel_delivers_messages_then_closed() {
        let mut bytes = Vec::new();
        crate::ipc::frame::write_message(&mut bytes, &Message::Ping).unwrap();
        crate::ipc::frame::write_message(&mut bytes, &Message::Pong).unwrap();
        let (tx, rx) = mpsc::channel();
        let handler: Handler = Arc::new(move |ev| {
            let tag = match ev {
                ChannelEvent::Message(Message::Ping) => "ping",
                ChannelEvent::Message(Message::Pong) => "pong",
                ChannelEvent::Message(_) => "other",
                ChannelEvent::Closed => "closed",
                ChannelEvent::Error(_) => "error",
                ChannelEvent::Stalled { .. } => "stalled",
            };
            let _ = tx.send(tag);
        });
        let ep = Endpoint::stream(
            Box::new(Scripted { data: std::io::Cursor::new(bytes) }),
            Box::new(std::io::sink()),
        );
        let _handle = register("test-pump", ep, handler);
        let timeout = Duration::from_secs(5);
        assert_eq!(rx.recv_timeout(timeout).unwrap(), "ping");
        assert_eq!(rx.recv_timeout(timeout).unwrap(), "pong");
        assert_eq!(rx.recv_timeout(timeout).unwrap(), "closed");
    }

    #[cfg(unix)]
    #[test]
    fn fd_channel_round_trips_through_the_reactor() {
        use std::io::Write as _;
        use std::os::unix::io::AsRawFd;
        use std::os::unix::net::UnixStream;
        let (ours, theirs) = UnixStream::pair().unwrap();
        let rfd = ours.as_raw_fd();
        let reader = Box::new(ours.try_clone().unwrap());
        let (tx, rx) = mpsc::channel();
        let handler: Handler = Arc::new(move |ev| {
            let tag = match ev {
                ChannelEvent::Message(Message::Ping) => "ping".to_string(),
                ChannelEvent::Message(_) => "other".into(),
                ChannelEvent::Closed => "closed".into(),
                ChannelEvent::Error(e) => format!("error: {e}"),
                ChannelEvent::Stalled { .. } => "stalled".into(),
            };
            let _ = tx.send(tag);
        });
        let handle =
            register("test-fd", Endpoint::with_fds(reader, Box::new(ours), rfd, rfd), handler);

        // Outbound: enqueue a frame, the reactor flushes it to the peer.
        let mut frame = Vec::new();
        crate::ipc::frame::write_message(&mut frame, &Message::Shutdown).unwrap();
        handle.send_bytes(&frame).unwrap();
        assert!(handle.wait_outbox_below(0, Duration::from_secs(5)), "outbox must drain");
        let mut peer = theirs;
        peer.set_nonblocking(false).unwrap();
        let got = crate::ipc::frame::read_message(&mut peer).unwrap();
        assert_eq!(got, Some(Message::Shutdown));

        // Inbound: the peer writes a frame, then closes.
        crate::ipc::frame::write_message(&mut peer, &Message::Ping).unwrap();
        drop(peer);
        let timeout = Duration::from_secs(5);
        assert_eq!(rx.recv_timeout(timeout).unwrap(), "ping");
        assert_eq!(rx.recv_timeout(timeout).unwrap(), "closed");
        assert!(handle.is_closed());
    }

    #[cfg(unix)]
    #[test]
    fn stall_deadline_fires_on_a_silent_channel() {
        use std::os::unix::io::AsRawFd;
        use std::os::unix::net::UnixStream;
        let (ours, _peer_keepalive) = UnixStream::pair().unwrap();
        let rfd = ours.as_raw_fd();
        let reader = Box::new(ours.try_clone().unwrap());
        let (tx, rx) = mpsc::channel();
        let handler: Handler = Arc::new(move |ev| {
            if let ChannelEvent::Stalled { silent_for } = ev {
                let _ = tx.send(silent_for);
            }
        });
        let handle = register(
            "test-stall",
            Endpoint::with_fds(reader, Box::new(ours), rfd, rfd),
            handler,
        );
        handle.arm_stall(Some(Duration::from_millis(50)));
        let silent = rx.recv_timeout(Duration::from_secs(5)).expect("stall event");
        assert!(silent >= Duration::from_millis(40), "silent for {silent:?}");
    }

    #[test]
    fn force_pump_scope_downgrades_fd_endpoints() {
        #[cfg(unix)]
        {
            use std::os::unix::io::AsRawFd;
            use std::os::unix::net::UnixStream;
            let _guard = force_pump_scope();
            let (ours, peer) = UnixStream::pair().unwrap();
            let rfd = ours.as_raw_fd();
            let reader = Box::new(ours.try_clone().unwrap());
            let (tx, rx) = mpsc::channel();
            let handler: Handler = Arc::new(move |ev| {
                if matches!(ev, ChannelEvent::Closed) {
                    let _ = tx.send(());
                }
            });
            let before = PUMP_THREADS.load(Ordering::SeqCst);
            let _handle = register(
                "test-forced",
                Endpoint::with_fds(reader, Box::new(ours), rfd, rfd),
                handler,
            );
            assert!(
                PUMP_THREADS.load(Ordering::SeqCst) > before
                    || rx.recv_timeout(Duration::from_millis(200)).is_err(),
                "forced registration must take the pump path"
            );
            drop(peer);
            rx.recv_timeout(Duration::from_secs(5)).expect("closed event from pump");
        }
    }

    #[test]
    fn backpressure_wait_returns_when_channel_closes() {
        let mut bytes = Vec::new();
        crate::ipc::frame::write_message(&mut bytes, &Message::Ping).unwrap();
        let handler: Handler = Arc::new(|_| {});
        let ep = Endpoint::stream(
            Box::new(Scripted { data: std::io::Cursor::new(bytes) }),
            Box::new(std::io::sink()),
        );
        let handle = register("test-bp", ep, handler);
        // Pump channels write through directly, so the outbox stays empty
        // and the wait returns immediately.
        assert!(handle.wait_outbox_below(0, Duration::from_millis(100)));
    }
}
