//! `availableCores()` — the paper's well-behaved alternative to
//! `parallel::detectCores()`.
//!
//! The paper (section "Results") stresses that defaulting to *all* detected
//! cores "wreaks havoc on multi-tenant compute systems"; `availableCores()`
//! instead respects every known option/environment variable that limits
//! parallelism (job-scheduler allocations, container quotas, explicit user
//! settings) and only then falls back to the detected count.

use std::env;

/// Environment variables consulted, most specific first.  Mirrors
/// `parallelly::availableCores()`'s documented lookup order, adapted to this
/// runtime's names plus the standard scheduler variables.
const ENV_VARS: &[&str] = &[
    "RUSTURES_NUM_WORKERS",   // this framework's own override
    "R_FUTURE_AVAILABLECORES_FALLBACK_OVERRIDE", // test hook
    "SLURM_CPUS_PER_TASK",    // Slurm allocation
    "NSLOTS",                 // SGE
    "PBS_NUM_PPN",            // Torque/PBS
    "OMP_NUM_THREADS",        // OpenMP convention
    "MC_CORES",               // R's mc.cores convention
];

/// Number of parallel workers this process should use.
///
/// Returns the first parseable positive value among [`ENV_VARS`], otherwise
/// the detected hardware parallelism, and never less than 1.
pub fn available_cores() -> usize {
    for var in ENV_VARS {
        if let Ok(v) = env::var(var) {
            if let Ok(n) = v.trim().parse::<usize>() {
                if n >= 1 {
                    return n;
                }
            }
        }
    }
    detect_cores()
}

/// Raw detected hardware parallelism (the `detectCores()` analog — use
/// [`available_cores`] instead in defaults).
pub fn detect_cores() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    // Env mutation is process-global; serialize these tests.
    static ENV_LOCK: Mutex<()> = Mutex::new(());

    fn clear_all() {
        for v in ENV_VARS {
            env::remove_var(v);
        }
    }

    #[test]
    fn returns_at_least_one() {
        let _g = ENV_LOCK.lock().unwrap();
        clear_all();
        assert!(available_cores() >= 1);
    }

    #[test]
    fn respects_own_override_first() {
        let _g = ENV_LOCK.lock().unwrap();
        clear_all();
        env::set_var("SLURM_CPUS_PER_TASK", "8");
        env::set_var("RUSTURES_NUM_WORKERS", "3");
        assert_eq!(available_cores(), 3);
        clear_all();
    }

    #[test]
    fn respects_scheduler_allocation() {
        let _g = ENV_LOCK.lock().unwrap();
        clear_all();
        env::set_var("SLURM_CPUS_PER_TASK", "5");
        assert_eq!(available_cores(), 5);
        clear_all();
    }

    #[test]
    fn ignores_unparseable_and_zero() {
        let _g = ENV_LOCK.lock().unwrap();
        clear_all();
        env::set_var("RUSTURES_NUM_WORKERS", "zero");
        env::set_var("MC_CORES", "0");
        assert!(available_cores() >= 1);
        clear_all();
    }
}
