//! Locate the `rustures` binary for spawning worker processes.
//!
//! Test/bench/example binaries live under `target/<profile>/{deps,examples}`
//! while the coordinator binary is `target/<profile>/rustures`; workers are
//! re-executions of that binary with the `worker` subcommand (the analog of
//! `Rscript -e 'parallel:::.workRSOCK()'` in the paper's PSOCK setup).

use std::path::PathBuf;

use crate::api::error::FutureError;

/// Path to the worker executable: `$RUSTURES_WORKER_EXE`, the current
/// executable if it *is* `rustures`, or `rustures` next to / above the
/// current executable (deps/examples directories).
pub fn worker_exe() -> Result<PathBuf, FutureError> {
    if let Ok(p) = std::env::var("RUSTURES_WORKER_EXE") {
        let p = PathBuf::from(p);
        if p.exists() {
            return Ok(p);
        }
        return Err(FutureError::Launch(format!(
            "RUSTURES_WORKER_EXE={} does not exist",
            p.display()
        )));
    }
    let exe = std::env::current_exe()
        .map_err(|e| FutureError::Launch(format!("current_exe: {e}")))?;
    let name = |p: &PathBuf| {
        p.file_stem().map(|s| s.to_string_lossy().into_owned()).unwrap_or_default()
    };
    if name(&exe) == "rustures" {
        return Ok(exe);
    }
    let mut dir = exe.parent().map(PathBuf::from);
    for _ in 0..3 {
        let Some(d) = dir else { break };
        let candidate = d.join("rustures");
        if candidate.exists() {
            return Ok(candidate);
        }
        dir = d.parent().map(PathBuf::from);
    }
    Err(FutureError::Launch(
        "cannot locate the 'rustures' worker binary; build it (cargo build) or set \
         RUSTURES_WORKER_EXE"
            .into(),
    ))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn explicit_env_missing_path_errors() {
        // Use a scoped fake; other tests don't set this var.
        std::env::set_var("RUSTURES_WORKER_EXE", "/definitely/not/here");
        let err = worker_exe().unwrap_err();
        assert!(err.to_string().contains("does not exist"));
        std::env::remove_var("RUSTURES_WORKER_EXE");
    }
}
