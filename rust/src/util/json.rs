//! A minimal, dependency-free JSON parser — enough to read
//! `artifacts/manifest.json` and scheduler job files.
//!
//! serde is not available in this offline image, so this is a small
//! recursive-descent parser over the JSON grammar (RFC 8259 subset: no
//! surrogate-pair unescaping beyond BMP, numbers as f64/i64).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    /// Integral number (fits i64 and had no '.', 'e').
    Int(i64),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(i) => Some(*i),
            Json::Num(f) if f.fract() == 0.0 => Some(*f as i64),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Int(i) => Some(*i as f64),
            Json::Num(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }
}

/// Parse error with byte offset for diagnostics.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "JSON parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

/// Parse a complete JSON document; trailing whitespace allowed, trailing
/// garbage is an error.
pub fn parse(input: &str) -> Result<Json, JsonError> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let d = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            code = code * 16
                                + (d as char)
                                    .to_digit(16)
                                    .ok_or_else(|| self.err("bad hex digit"))?;
                        }
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences byte by byte.
                    let start = self.pos - 1;
                    let len = utf8_len(b);
                    self.pos = start + len;
                    if self.pos > self.bytes.len() {
                        return Err(self.err("truncated UTF-8"));
                    }
                    let s = std::str::from_utf8(&self.bytes[start..self.pos])
                        .map_err(|_| self.err("invalid UTF-8"))?;
                    out.push_str(s);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        let mut is_float = false;
        while let Some(b) = self.peek() {
            match b {
                b'0'..=b'9' => self.pos += 1,
                b'.' | b'e' | b'E' | b'+' | b'-' => {
                    is_float = true;
                    self.pos += 1;
                }
                _ => break,
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if is_float {
            text.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
        } else {
            text.parse::<i64>().map(Json::Int).map_err(|_| self.err("bad integer"))
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Serialize a [`Json`] value (used by the scheduler's job/result files).
pub fn to_string(v: &Json) -> String {
    let mut s = String::new();
    write_json(v, &mut s);
    s
}

fn write_json(v: &Json, out: &mut String) {
    match v {
        Json::Null => out.push_str("null"),
        Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
        Json::Int(i) => out.push_str(&i.to_string()),
        Json::Num(f) => out.push_str(&format!("{f}")),
        Json::Str(s) => write_escaped(s, out),
        Json::Arr(a) => {
            out.push('[');
            for (i, item) in a.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_json(item, out);
            }
            out.push(']');
        }
        Json::Obj(m) => {
            out.push('{');
            for (i, (k, val)) in m.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                write_escaped(k, out);
                out.push(':');
                write_json(val, out);
            }
            out.push('}');
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse(" 42 ").unwrap(), Json::Int(42));
        assert_eq!(parse("-7").unwrap(), Json::Int(-7));
        assert_eq!(parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(parse("1e3").unwrap(), Json::Num(1000.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let doc = r#"{"a": [1, 2, {"b": "x\ny"}], "c": null}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x\ny")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parses_unicode_escape() {
        assert_eq!(parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn parses_utf8_passthrough() {
        assert_eq!(parse("\"héllo→\"").unwrap(), Json::Str("héllo→".into()));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
        assert!(parse("nul").is_err());
    }

    #[test]
    fn roundtrips() {
        let doc = r#"{"arr":[1,2.5,"s"],"b":true,"n":null}"#;
        let v = parse(doc).unwrap();
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn escapes_on_write() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }

    #[test]
    fn real_manifest_shape() {
        let doc = r#"{"format":1,"entries":[{"name":"slow_fcn","file":"slow_fcn.hlo.txt",
            "args":[{"shape":[128,128],"dtype":"float32"}],
            "outputs":[{"shape":[128,128],"dtype":"float32"}],"sha256":"ab"}]}"#;
        let v = parse(doc).unwrap();
        assert_eq!(v.get("format").unwrap().as_i64(), Some(1));
        let entries = v.get("entries").unwrap().as_arr().unwrap();
        assert_eq!(entries[0].get("name").unwrap().as_str(), Some("slow_fcn"));
        let shape = entries[0].get("args").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape.iter().map(|j| j.as_i64().unwrap()).collect::<Vec<_>>(), vec![128, 128]);
    }
}
