//! Support substrates: a minimal JSON parser (no serde on this image), UUIDs,
//! and the `availableCores()` environment-variable discipline from the paper.

pub mod cores;
pub mod exe;
pub mod json;
pub mod uuid;

pub use cores::available_cores;
pub use uuid::uuid_v4;
