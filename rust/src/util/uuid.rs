//! UUIDs for futures and jobs (the paper's framework uses `digest`-derived
//! UUIDs; we derive v4-format ids from OS entropy + a counter).

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A v4-format UUID string, unique within and across processes
/// (time + pid + counter mixed through splitmix64).
pub fn uuid_v4() -> String {
    let c = COUNTER.fetch_add(1, Ordering::Relaxed);
    let t = SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default();
    let seed = t.as_nanos() as u64 ^ (std::process::id() as u64) << 32 ^ c;
    let a = splitmix64(seed);
    let b = splitmix64(a);
    let bytes = [a.to_le_bytes(), b.to_le_bytes()].concat();
    format!(
        "{:02x}{:02x}{:02x}{:02x}-{:02x}{:02x}-4{:01x}{:02x}-{:02x}{:02x}-{:02x}{:02x}{:02x}{:02x}{:02x}{:02x}",
        bytes[0], bytes[1], bytes[2], bytes[3],
        bytes[4], bytes[5],
        bytes[6] & 0x0f, bytes[7],
        (bytes[8] & 0x3f) | 0x80, bytes[9],
        bytes[10], bytes[11], bytes[12], bytes[13], bytes[14], bytes[15],
    )
}

/// splitmix64 — also used to expand user seeds into RNG state.
pub fn splitmix64(mut x: u64) -> u64 {
    x = x.wrapping_add(0x9e3779b97f4a7c15);
    x = (x ^ (x >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
    x = (x ^ (x >> 27)).wrapping_mul(0x94d049bb133111eb);
    x ^ (x >> 31)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;

    #[test]
    fn uuids_are_unique() {
        let set: HashSet<String> = (0..1000).map(|_| uuid_v4()).collect();
        assert_eq!(set.len(), 1000);
    }

    #[test]
    fn uuid_format() {
        let u = uuid_v4();
        assert_eq!(u.len(), 36);
        assert_eq!(u.matches('-').count(), 4);
        assert_eq!(&u[14..15], "4"); // version nibble
    }

    #[test]
    fn splitmix_is_deterministic() {
        assert_eq!(splitmix64(42), splitmix64(42));
        assert_ne!(splitmix64(42), splitmix64(43));
    }
}
