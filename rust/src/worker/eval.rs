//! The expression evaluator — what a worker does to resolve a future.
//!
//! Evaluates an [`Expr`] against the captured globals, with local `Let`
//! scopes, R-flavored error messages, RNG-stream semantics, condition
//! capture, and compiled-kernel dispatch through the PJRT runtime handle.

use std::sync::Arc;

use crate::api::conditions::{CaptureBuffer, Condition, ConditionKind};
use crate::api::env::Env;
use crate::api::error::EvalError;
use crate::api::expr::{EmitKind, Expr, PrimOp, RngDist};
use crate::api::rng::RngStream;
use crate::api::value::{Tensor, Value};
use crate::runtime::RuntimeHandle;

/// RNG context for one task.
pub struct RngCtx {
    /// `seed = TRUE` base seed; `None` means seed not set.
    seed: Option<u64>,
    /// Stream currently installed (lazily created on first draw).
    current: Option<RngStream>,
    /// Stream index for lazy creation.
    stream_index: u64,
}

impl RngCtx {
    pub fn new(seed: Option<u64>, stream_index: u64) -> Self {
        RngCtx { seed, current: None, stream_index }
    }

    fn stream(&mut self) -> &mut RngStream {
        if self.current.is_none() {
            let s = match self.seed {
                Some(seed) => RngStream::nth_stream(seed, self.stream_index),
                // Unseeded: nondeterministic fallback (and the caller flags
                // the paper's "UnexpectedRandomNumbers" warning).
                None => {
                    let t = std::time::SystemTime::now()
                        .duration_since(std::time::UNIX_EPOCH)
                        .unwrap_or_default()
                        .as_nanos() as u64;
                    RngStream::from_seed(t ^ (std::process::id() as u64) << 32)
                }
            };
            self.current = Some(s);
        }
        self.current.as_mut().unwrap()
    }
}

/// Evaluation context threading capture, RNG, and the kernel runtime.
pub struct EvalCtx<'a, 'b> {
    pub buffer: &'a mut CaptureBuffer,
    pub rng: RngCtx,
    pub kernels: Option<RuntimeHandle>,
    /// Live relay hook for `immediateCondition`s (backends that support it).
    pub on_immediate: Option<&'b mut dyn FnMut(&Condition)>,
    /// In-process progress cell: the evaluator bumps its epoch at every
    /// yield point (between `MapChunk` elements) and honors its cancel
    /// flag by failing with [`crate::liveness::WORKER_CANCEL_ERROR`].
    pub liveness: Option<Arc<crate::liveness::TaskLiveness>>,
    /// Remote liveness hook: called at every yield point so `run_worker`
    /// can emit heartbeat frames without a dedicated heartbeat thread.
    pub on_tick: Option<&'b mut dyn FnMut()>,
}

impl EvalCtx<'_, '_> {
    /// A cooperative yield point: advance the progress epoch, let the
    /// worker loop emit a heartbeat, and honor a pending cancel request.
    fn yield_point(&mut self) -> Result<(), EvalError> {
        if let Some(cell) = &self.liveness {
            cell.tick();
            if cell.is_cancelled() {
                return Err(EvalError::new(crate::liveness::WORKER_CANCEL_ERROR));
            }
        }
        if let Some(f) = self.on_tick.as_mut() {
            f();
        }
        Ok(())
    }
}

/// Local scope stack: innermost binding wins; globals behind it.
struct Scope<'a> {
    globals: &'a Env,
    locals: Vec<(String, Value)>,
}

impl<'a> Scope<'a> {
    fn lookup(&self, name: &str) -> Option<&Value> {
        self.locals
            .iter()
            .rev()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v)
            .or_else(|| self.globals.get(name))
    }
}

/// Run `f` with RNG substream `index` installed, restoring the previous
/// stream after — the one save/install/restore sequence shared by
/// `WithRngStream` and per-element `MapChunk` evaluation, so the two can
/// never drift.
fn with_stream_index<T>(
    ctx: &mut EvalCtx<'_, '_>,
    index: u64,
    f: impl FnOnce(&mut EvalCtx<'_, '_>) -> T,
) -> T {
    let saved = ctx.rng.current.take();
    let saved_index = ctx.rng.stream_index;
    ctx.rng.stream_index = index;
    let out = f(&mut *ctx);
    ctx.rng.current = saved;
    ctx.rng.stream_index = saved_index;
    out
}

/// Evaluate `expr` under `globals`.
pub fn evaluate(
    expr: &Expr,
    globals: &Env,
    ctx: &mut EvalCtx<'_, '_>,
) -> Result<Value, EvalError> {
    let mut scope = Scope { globals, locals: Vec::new() };
    eval(expr, &mut scope, ctx)
}

fn eval(expr: &Expr, scope: &mut Scope, ctx: &mut EvalCtx<'_, '_>) -> Result<Value, EvalError> {
    match expr {
        Expr::Lit(v) => Ok(v.clone()),
        Expr::Var(name) => scope
            .lookup(name)
            .cloned()
            .ok_or_else(|| EvalError::new(format!("object '{name}' not found"))),
        Expr::Let { name, value, body } => {
            let v = eval(value, scope, ctx)?;
            scope.locals.push((name.clone(), v));
            let out = eval(body, scope, ctx);
            scope.locals.pop();
            out
        }
        Expr::Seq(items) => {
            let mut last = Value::Unit;
            for item in items {
                last = eval(item, scope, ctx)?;
            }
            Ok(last)
        }
        Expr::List(items) => {
            let mut out = Vec::with_capacity(items.len());
            for item in items {
                out.push(eval(item, scope, ctx)?);
            }
            Ok(Value::List(out))
        }
        Expr::Index { list, index } => {
            let lv = eval(list, scope, ctx)?;
            let iv = eval(index, scope, ctx)?;
            let i = iv
                .as_i64()
                .ok_or_else(|| EvalError::new("invalid subscript: expected an integer"))?;
            match &lv {
                Value::List(items) => items.get(i as usize).cloned().ok_or_else(|| {
                    EvalError::new(format!("subscript out of bounds: {i} of {}", items.len()))
                }),
                Value::Tensor(t) if t.rank() >= 1 => {
                    // Row indexing: returns the i-th slice along axis 0.
                    let rows = t.shape[0];
                    if i < 0 || i as usize >= rows {
                        return Err(EvalError::new(format!(
                            "subscript out of bounds: {i} of {rows}"
                        )));
                    }
                    let stride: usize = t.shape[1..].iter().product();
                    let start = i as usize * stride;
                    // Single copy straight into the shared allocation.
                    let data: Arc<[f32]> = Arc::from(&t.data[start..start + stride]);
                    Ok(Value::Tensor(
                        Tensor::from_shared(t.shape[1..].to_vec(), data)
                            .map_err(EvalError::new)?,
                    ))
                }
                other => Err(EvalError::new(format!(
                    "object of type '{}' is not subsettable",
                    other.type_name()
                ))),
            }
        }
        Expr::Call { kernel, args } => {
            let mut argv = Vec::with_capacity(args.len());
            for a in args {
                argv.push(eval(a, scope, ctx)?);
            }
            // Lazy runtime resolution: workers only pay the PJRT load +
            // artifact compile cost when a task actually calls a kernel.
            let rt = ctx
                .kernels
                .clone()
                .or_else(|| crate::runtime::global().map(|rt| rt.handle()));
            match rt {
                Some(rt) => rt.execute(kernel, argv),
                None => Err(EvalError::new(format!(
                    "could not find function \"{kernel}\" (no PJRT runtime loaded; run `make artifacts`)"
                ))),
            }
        }
        Expr::Prim { op, args } => {
            let mut argv = Vec::with_capacity(args.len());
            for a in args {
                argv.push(eval(a, scope, ctx)?);
            }
            apply_prim(*op, &argv)
        }
        Expr::If { cond, then, otherwise } => {
            let c = eval(cond, scope, ctx)?;
            match c.as_bool() {
                Some(true) => eval(then, scope, ctx),
                Some(false) => eval(otherwise, scope, ctx),
                None => Err(EvalError::new("argument is not interpretable as logical")),
            }
        }
        Expr::DynLookup(inner) => {
            let nv = eval(inner, scope, ctx)?;
            let name = nv
                .as_str()
                .ok_or_else(|| EvalError::new("invalid first argument to get()"))?;
            scope
                .lookup(name)
                .cloned()
                .ok_or_else(|| EvalError::new(format!("object '{name}' not found")))
        }
        Expr::Emit { kind, message } => {
            let mv = eval(message, scope, ctx)?;
            let text = render(&mv);
            match kind {
                EmitKind::Stdout => ctx.buffer.capture_stdout(&text),
                EmitKind::Message => ctx.buffer.signal(ConditionKind::Message, text),
                EmitKind::Warning => ctx.buffer.signal(ConditionKind::Warning, text),
                EmitKind::Progress => {
                    ctx.buffer.signal(ConditionKind::Immediate, text);
                    // Live-relay hook: drain what we just signaled.
                    if ctx.on_immediate.is_some() {
                        let drained = ctx.buffer.drain_immediate();
                        if let Some(f) = ctx.on_immediate.as_mut() {
                            for c in &drained {
                                f(c);
                            }
                        }
                    }
                }
            }
            Ok(Value::Unit)
        }
        Expr::Stop(inner) => {
            let mv = eval(inner, scope, ctx)?;
            Err(EvalError::new(render(&mv)))
        }
        Expr::Rng { dist, shape } => {
            if ctx.rng.seed.is_none() {
                ctx.buffer.rng_used = true;
            }
            let n: usize = shape.iter().product();
            let stream = ctx.rng.stream();
            // Collect straight into the shared allocation (single alloc,
            // no Vec→Arc copy).
            let data: Arc<[f32]> = match dist {
                RngDist::Unif => (0..n).map(|_| stream.next_unif() as f32).collect(),
                RngDist::Norm => (0..n).map(|_| stream.next_norm() as f32).collect(),
            };
            Ok(Value::Tensor(Tensor::from_parts(shape.clone(), data)))
        }
        Expr::WithRngStream { index, body } => {
            // Per-element substream: install stream `index`, restore after.
            with_stream_index(ctx, *index, |ctx| eval(body, scope, ctx))
        }
        Expr::MapChunk { param, body, elements, base_index } => {
            // Bind each element (an Arc-cheap Value clone) to `param`,
            // evaluate the shared body, and — when this task is seeded —
            // do it under the element's global RNG substream
            // `base_index + i`, so results are chunking-invariant
            // (identical to the per-element
            // `WithRngStream(let param = el in body)` desugaring).
            let seeded = ctx.rng.seed.is_some();
            let mut out = Vec::with_capacity(elements.len());
            // One scope slot (one String allocation) serves the whole
            // chunk, rebound per element; the single pop below is the only
            // cleanup point, even on an element error.
            scope.locals.push((param.clone(), Value::Unit));
            let mut failed = None;
            for (i, el) in elements.iter().enumerate() {
                // Element boundary = the liveness plane's yield point:
                // heartbeat/epoch tick plus the cooperative-cancel check.
                if let Err(e) = ctx.yield_point() {
                    failed = Some(e);
                    break;
                }
                scope.locals.last_mut().expect("chunk param slot").1 = el.clone();
                let r = if seeded {
                    with_stream_index(ctx, *base_index + i as u64, |ctx| {
                        eval(body, scope, ctx)
                    })
                } else {
                    eval(body, scope, ctx)
                };
                match r {
                    Ok(v) => out.push(v),
                    Err(e) => {
                        failed = Some(e);
                        break;
                    }
                }
            }
            scope.locals.pop();
            match failed {
                Some(e) => Err(e),
                None => Ok(Value::List(out)),
            }
        }
        Expr::Spin { millis } => {
            // Spin in short slices with yield points between them: a busy
            // worker keeps proving liveness (heartbeats) — only a genuinely
            // silent hang trips the stall detector — and honors cooperative
            // cancellation mid-burn.
            let until = std::time::Instant::now() + std::time::Duration::from_millis(*millis);
            loop {
                ctx.yield_point()?;
                let now = std::time::Instant::now();
                if now >= until {
                    break;
                }
                let slice_end = now + (until - now).min(std::time::Duration::from_millis(5));
                while std::time::Instant::now() < slice_end {
                    std::hint::spin_loop();
                }
            }
            Ok(Value::Unit)
        }
        Expr::Sleep { millis } => {
            // Sliced for the same reason as `Spin`: liveness while blocked.
            let until = std::time::Instant::now() + std::time::Duration::from_millis(*millis);
            loop {
                ctx.yield_point()?;
                let now = std::time::Instant::now();
                if now >= until {
                    break;
                }
                std::thread::sleep((until - now).min(std::time::Duration::from_millis(10)));
            }
            Ok(Value::Unit)
        }
        Expr::Work { iters } => {
            // Fixed CPU demand: splitmix rounds the optimizer cannot elide.
            let mut acc = 0u64;
            for i in 0..*iters {
                acc = acc.wrapping_add(crate::util::uuid::splitmix64(i ^ acc));
            }
            std::hint::black_box(acc);
            Ok(Value::Unit)
        }
        Expr::ChaosKill { marker } => {
            if let Some(m) = marker {
                if std::path::Path::new(m).exists() {
                    // The kill already fired on an earlier attempt: survive
                    // (a supervised retry takes this branch).
                    return Ok(Value::I64(0));
                }
                // Create the marker BEFORE dying so the retried run sees it.
                let _ = std::fs::write(m, b"killed");
            }
            if crate::backend::supervisor::kill_exits_process() {
                // Disposable worker process: die like a real crash — the
                // coordinator's reader sees EOF / the scheduler harvests a
                // nonzero exit.
                std::process::exit(137);
            }
            // In-process evaluation: surface the sentinel.  The thread
            // pool's worker loop turns it into a genuine worker-thread
            // death; under plan(sequential) it is just an eval error (there
            // is no disposable worker to kill).
            Err(EvalError::new(crate::backend::supervisor::WORKER_KILL_ERROR))
        }
        Expr::ChaosHang { millis, marker } => {
            if let Some(m) = marker {
                if std::path::Path::new(m).exists() {
                    // The hang already fired on an earlier attempt: proceed
                    // immediately (a post-stall retry takes this branch).
                    return Ok(Value::I64(0));
                }
                // Create the marker BEFORE hanging so the retried run sees it.
                let _ = std::fs::write(m, b"hung");
            }
            // Hang *silently*: no ticks, no heartbeats — exactly the
            // pathology the stall detector exists to catch.  We do honor
            // cooperative cancellation between sleep slices so an
            // in-process hang can still be timed out.
            let until = std::time::Instant::now() + std::time::Duration::from_millis(*millis);
            loop {
                if let Some(cell) = &ctx.liveness {
                    if cell.is_cancelled() {
                        return Err(EvalError::new(crate::liveness::WORKER_CANCEL_ERROR));
                    }
                }
                let now = std::time::Instant::now();
                if now >= until {
                    break;
                }
                std::thread::sleep((until - now).min(std::time::Duration::from_millis(10)));
            }
            Ok(Value::I64(0))
        }
        Expr::Await { future_id } => {
            // A pipelined dependency: its outcome was bound into the task
            // environment under a reserved key — either at creation (the
            // dependency was already resolved) or by the worker's
            // Forward-collection loop before evaluation started.
            if let Some(v) = scope.lookup(&crate::ipc::pipeline_ok_key(future_id)) {
                return Ok(v.clone());
            }
            if let Some(v) = scope.lookup(&crate::ipc::pipeline_err_key(future_id)) {
                let msg = match v {
                    Value::Str(s) => s.clone(),
                    other => format!("{other}"),
                };
                return Err(EvalError::new(msg));
            }
            Err(EvalError::new(format!(
                "unresolved pipelined dependency '{future_id}' (no forwarded outcome)"
            )))
        }
    }
}

/// Render a value for `cat()`/`message()`/`stop()`.
fn render(v: &Value) -> String {
    match v {
        Value::Str(s) => s.clone(),
        other => format!("{other}"),
    }
}

fn num2(op: &str, a: &Value, b: &Value) -> Result<(f64, f64), EvalError> {
    match (a.as_f64(), b.as_f64()) {
        (Some(x), Some(y)) => Ok((x, y)),
        _ => Err(EvalError::new(format!(
            "non-numeric argument to binary operator '{op}' ({} vs {})",
            a.type_name(),
            b.type_name()
        ))),
    }
}

fn arity(op: PrimOp, want: usize, got: usize) -> Result<(), EvalError> {
    if want == got {
        Ok(())
    } else {
        Err(EvalError::new(format!("{op:?} expects {want} argument(s), got {got}")))
    }
}

/// Element-wise tensor/scalar arithmetic dispatch.
fn tensor_binop(
    op: PrimOp,
    f: impl Fn(f32, f32) -> f32,
    a: &Value,
    b: &Value,
) -> Option<Result<Value, EvalError>> {
    match (a, b) {
        (Value::Tensor(x), Value::Tensor(y)) => {
            if x.shape != y.shape {
                return Some(Err(EvalError::new(format!(
                    "non-conformable arrays: {:?} vs {:?}",
                    x.shape, y.shape
                ))));
            }
            let data = x.data.iter().zip(&y.data[..]).map(|(p, q)| f(*p, *q)).collect();
            Some(Ok(Value::Tensor(Tensor::from_parts(x.shape.clone(), data))))
        }
        (Value::Tensor(x), other) | (other, Value::Tensor(x)) => {
            let s = match other.as_f64() {
                Some(s) => s as f32,
                None => {
                    return Some(Err(EvalError::new(format!(
                        "non-numeric argument to binary operator '{op:?}'"
                    ))))
                }
            };
            // Preserve operand order for non-commutative ops.
            let left_is_tensor = matches!(a, Value::Tensor(_));
            let data = x
                .data
                .iter()
                .map(|p| if left_is_tensor { f(*p, s) } else { f(s, *p) })
                .collect();
            Some(Ok(Value::Tensor(Tensor::from_parts(x.shape.clone(), data))))
        }
        _ => None,
    }
}

fn apply_prim(op: PrimOp, args: &[Value]) -> Result<Value, EvalError> {
    use PrimOp::*;
    match op {
        Add | Sub | Mul | Div => {
            arity(op, 2, args.len())?;
            let (a, b) = (&args[0], &args[1]);
            let f = match op {
                Add => |x: f32, y: f32| x + y,
                Sub => |x: f32, y: f32| x - y,
                Mul => |x: f32, y: f32| x * y,
                _ => |x: f32, y: f32| x / y,
            };
            if let Some(r) = tensor_binop(op, f, a, b) {
                return r;
            }
            // Integer arithmetic stays integral except division.
            if let (Value::I64(x), Value::I64(y)) = (a, b) {
                return Ok(match op {
                    Add => Value::I64(x + y),
                    Sub => Value::I64(x - y),
                    Mul => Value::I64(x * y),
                    _ => Value::F64(*x as f64 / *y as f64),
                });
            }
            let name = match op {
                Add => "+",
                Sub => "-",
                Mul => "*",
                _ => "/",
            };
            let (x, y) = num2(name, a, b)?;
            Ok(Value::F64(match op {
                Add => x + y,
                Sub => x - y,
                Mul => x * y,
                _ => x / y,
            }))
        }
        Neg => {
            arity(op, 1, args.len())?;
            match &args[0] {
                Value::I64(x) => Ok(Value::I64(-x)),
                Value::F64(x) => Ok(Value::F64(-x)),
                Value::Tensor(t) => Ok(Value::Tensor(Tensor::from_parts(
                    t.shape.clone(),
                    t.data.iter().map(|x| -x).collect(),
                ))),
                other => Err(EvalError::new(format!(
                    "invalid argument to unary operator '-' ({})",
                    other.type_name()
                ))),
            }
        }
        Lt | Le => {
            arity(op, 2, args.len())?;
            let (x, y) = num2(if op == Lt { "<" } else { "<=" }, &args[0], &args[1])?;
            Ok(Value::Bool(if op == Lt { x < y } else { x <= y }))
        }
        Eq => {
            arity(op, 2, args.len())?;
            Ok(Value::Bool(match (&args[0], &args[1]) {
                (Value::Str(a), Value::Str(b)) => a == b,
                (Value::Bool(a), Value::Bool(b)) => a == b,
                (a, b) => match (a.as_f64(), b.as_f64()) {
                    (Some(x), Some(y)) => x == y,
                    _ => a == b,
                },
            }))
        }
        Not => {
            arity(op, 1, args.len())?;
            args[0]
                .as_bool()
                .map(|b| Value::Bool(!b))
                .ok_or_else(|| EvalError::new("invalid argument type to '!'"))
        }
        Len => {
            arity(op, 1, args.len())?;
            Ok(Value::I64(match &args[0] {
                Value::List(v) => v.len() as i64,
                Value::Str(s) => s.chars().count() as i64,
                Value::Tensor(t) => t.len() as i64,
                _ => 1,
            }))
        }
        Sum | Mean => {
            arity(op, 1, args.len())?;
            let (total, n) = match &args[0] {
                Value::Tensor(t) => (t.data.iter().map(|x| *x as f64).sum::<f64>(), t.len()),
                Value::List(items) => {
                    let mut total = 0.0;
                    for item in items {
                        total += item.as_f64().ok_or_else(|| {
                            EvalError::new("invalid 'type' (non-numeric) of argument")
                        })?;
                    }
                    (total, items.len())
                }
                other => (
                    other.as_f64().ok_or_else(|| {
                        EvalError::new("invalid 'type' (non-numeric) of argument")
                    })?,
                    1,
                ),
            };
            Ok(Value::F64(if op == Sum { total } else { total / n.max(1) as f64 }))
        }
        Sqrt => {
            arity(op, 1, args.len())?;
            match &args[0] {
                Value::Tensor(t) => Ok(Value::Tensor(Tensor::from_parts(
                    t.shape.clone(),
                    t.data.iter().map(|x| x.sqrt()).collect(),
                ))),
                other => {
                    let x = other.as_f64().ok_or_else(|| {
                        EvalError::new("non-numeric argument to mathematical function")
                    })?;
                    Ok(Value::F64(x.sqrt()))
                }
            }
        }
        Concat => {
            let mut out = String::new();
            for a in args {
                out.push_str(&render(a));
            }
            Ok(Value::Str(out))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run(expr: &Expr, env: &Env) -> Result<Value, EvalError> {
        let mut buf = CaptureBuffer::new();
        let mut ctx = EvalCtx {
            buffer: &mut buf,
            rng: RngCtx::new(Some(1), 0),
            kernels: None,
            on_immediate: None,
            liveness: None,
            on_tick: None,
        };
        evaluate(expr, env, &mut ctx)
    }

    #[test]
    fn arithmetic_and_scoping() {
        let mut env = Env::new();
        env.insert("x", 10.0);
        // let a = x * 2 in a + 1  →  21
        let e = Expr::let_in(
            "a",
            Expr::mul(Expr::var("x"), Expr::lit(2.0)),
            Expr::add(Expr::var("a"), Expr::lit(1.0)),
        );
        assert_eq!(run(&e, &env).unwrap(), Value::F64(21.0));
    }

    #[test]
    fn integer_arithmetic_stays_integral() {
        let env = Env::new();
        assert_eq!(run(&Expr::add(Expr::lit(2i64), Expr::lit(3i64)), &env).unwrap(), Value::I64(5));
        assert_eq!(
            run(&Expr::div(Expr::lit(1i64), Expr::lit(2i64)), &env).unwrap(),
            Value::F64(0.5)
        );
    }

    #[test]
    fn tensor_elementwise_ops() {
        let mut env = Env::new();
        env.insert("t", Tensor::new(vec![3], vec![1.0, 2.0, 3.0]).unwrap());
        let e = Expr::mul(Expr::var("t"), Expr::lit(2.0));
        let v = run(&e, &env).unwrap();
        assert_eq!(v.as_tensor().unwrap().data.to_vec(), vec![2.0, 4.0, 6.0]);
        // scalar - tensor preserves order
        let e2 = Expr::sub(Expr::lit(10.0), Expr::var("t"));
        assert_eq!(
            run(&e2, &env).unwrap().as_tensor().unwrap().data.to_vec(),
            vec![9.0, 8.0, 7.0]
        );
    }

    #[test]
    fn missing_variable_mimics_r_error() {
        let env = Env::new();
        let err = run(&Expr::var("k"), &env).unwrap_err();
        assert_eq!(err.message, "object 'k' not found");
        // The get("k") trap fails the same way at *runtime*.
        let err = run(&Expr::dyn_lookup(Expr::lit("k")), &env).unwrap_err();
        assert_eq!(err.message, "object 'k' not found");
    }

    #[test]
    fn dyn_lookup_finds_captured_global() {
        let mut env = Env::new();
        env.insert("k", 42i64);
        assert_eq!(run(&Expr::dyn_lookup(Expr::lit("k")), &env).unwrap(), Value::I64(42));
    }

    #[test]
    fn non_numeric_math_matches_paper_example() {
        // paper: log("24") → "non-numeric argument to mathematical function"
        let mut env = Env::new();
        env.insert("x", "24");
        let err = run(&Expr::prim(PrimOp::Sqrt, vec![Expr::var("x")]), &env).unwrap_err();
        assert_eq!(err.message, "non-numeric argument to mathematical function");
    }

    #[test]
    fn stop_raises_eval_error() {
        let env = Env::new();
        let err = run(&Expr::stop(Expr::lit("boom")), &env).unwrap_err();
        assert_eq!(err.message, "boom");
    }

    #[test]
    fn emit_captures_in_order() {
        let env = Env::new();
        let e = Expr::seq(vec![
            Expr::cat(Expr::lit("Hello world\n")),
            Expr::message(Expr::lit("The sum of 'x' is 55")),
            Expr::warning(Expr::lit("Missing values were omitted")),
            Expr::cat(Expr::lit("Bye bye\n")),
            Expr::lit(55i64),
        ]);
        let mut buf = CaptureBuffer::new();
        let mut ctx = EvalCtx {
            buffer: &mut buf,
            rng: RngCtx::new(None, 0),
            kernels: None,
            on_immediate: None,
            liveness: None,
            on_tick: None,
        };
        let v = evaluate(&e, &env, &mut ctx).unwrap();
        assert_eq!(v, Value::I64(55));
        let captured = buf.finish();
        assert_eq!(captured.stdout, "Hello world\nBye bye\n");
        assert_eq!(captured.conditions.len(), 2);
        assert_eq!(captured.conditions[0].kind, ConditionKind::Message);
        assert_eq!(captured.conditions[1].kind, ConditionKind::Warning);
    }

    #[test]
    fn seeded_rng_is_deterministic_unseeded_flags_misuse() {
        let env = Env::new();
        let draw = Expr::rnorm(3);

        let go = |seed: Option<u64>| {
            let mut buf = CaptureBuffer::new();
            let mut ctx = EvalCtx {
                buffer: &mut buf,
                rng: RngCtx::new(seed, 5),
                kernels: None,
                on_immediate: None,
                liveness: None,
                on_tick: None,
            };
            let v = evaluate(&draw, &env, &mut ctx).unwrap();
            (v, buf.finish().rng_used)
        };

        let (a, used_a) = go(Some(42));
        let (b, used_b) = go(Some(42));
        assert_eq!(a, b, "seeded draws must be reproducible");
        assert!(!used_a && !used_b, "seeded use is not misuse");

        let (_, used) = go(None);
        assert!(used, "unseeded RNG draw must be flagged");
    }

    #[test]
    fn with_rng_stream_is_chunking_invariant() {
        let env = Env::new();
        let body = |idx| Expr::with_rng_stream(idx, Expr::runif(2));
        let go = |exprs: Vec<Expr>| {
            let mut buf = CaptureBuffer::new();
            let mut ctx = EvalCtx {
                buffer: &mut buf,
                rng: RngCtx::new(Some(7), 0),
                kernels: None,
                on_immediate: None,
                liveness: None,
                on_tick: None,
            };
            evaluate(&Expr::list(exprs), &env, &mut ctx).unwrap()
        };
        // Elements 0..4 in one chunk...
        let all = go((0..4).map(body).collect());
        // ...must equal elements evaluated as two chunks.
        let c1 = go((0..2).map(body).collect());
        let c2 = go((2..4).map(body).collect());
        let mut combined = c1.as_list().unwrap().to_vec();
        combined.extend(c2.as_list().unwrap().to_vec());
        assert_eq!(all, Value::List(combined));
    }

    #[test]
    fn map_chunk_matches_per_element_desugaring() {
        use std::sync::Arc;
        // The first-class chunk must evaluate exactly like the old
        // per-element `WithRngStream(i, let x = el in body)` encoding.
        let env = Env::new();
        let body = Expr::add(Expr::var("x"), Expr::runif(2));
        let elements: Vec<Value> = (0..4i64).map(Value::I64).collect();

        let go = |expr: &Expr| {
            let mut buf = CaptureBuffer::new();
            let mut ctx = EvalCtx {
                buffer: &mut buf,
                rng: RngCtx::new(Some(11), 0),
                kernels: None,
                on_immediate: None,
                liveness: None,
                on_tick: None,
            };
            evaluate(expr, &env, &mut ctx).unwrap()
        };

        // New: one chunk covering elements 2..6 of a virtual map.
        let chunk = Expr::map_chunk("x", Arc::new(body.clone()), elements.clone(), 2);
        // Old: explicit per-element desugaring with the same global indices.
        let desugared = Expr::list(
            elements
                .iter()
                .enumerate()
                .map(|(i, el)| {
                    Expr::with_rng_stream(
                        2 + i as u64,
                        Expr::let_in("x", Expr::Lit(el.clone()), body.clone()),
                    )
                })
                .collect(),
        );
        assert_eq!(go(&chunk), go(&desugared));
    }

    #[test]
    fn map_chunk_element_error_propagates() {
        use std::sync::Arc;
        let env = Env::new();
        let body = Expr::if_else(
            Expr::prim(PrimOp::Eq, vec![Expr::var("x"), Expr::lit(1i64)]),
            Expr::stop(Expr::lit("element 1 failed")),
            Expr::var("x"),
        );
        let chunk = Expr::map_chunk(
            "x",
            Arc::new(body),
            (0..3i64).map(Value::I64).collect(),
            0,
        );
        let err = run(&chunk, &env).unwrap_err();
        assert_eq!(err.message, "element 1 failed");
    }

    #[test]
    fn list_index_and_len() {
        let env = Env::new();
        let e = Expr::index(
            Expr::list(vec![Expr::lit(10i64), Expr::lit(20i64)]),
            Expr::lit(1i64),
        );
        assert_eq!(run(&e, &env).unwrap(), Value::I64(20));
        let e = Expr::prim(PrimOp::Len, vec![Expr::list(vec![Expr::lit(1i64)])]);
        assert_eq!(run(&e, &env).unwrap(), Value::I64(1));
        let oob = Expr::index(Expr::list(vec![]), Expr::lit(0i64));
        assert!(run(&oob, &env).is_err());
    }

    #[test]
    fn tensor_row_indexing() {
        let mut env = Env::new();
        env.insert("m", Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap());
        let row = run(&Expr::index(Expr::var("m"), Expr::lit(1i64)), &env).unwrap();
        assert_eq!(row.as_tensor().unwrap().data.to_vec(), vec![4., 5., 6.]);
        assert_eq!(row.as_tensor().unwrap().shape, vec![3]);
    }

    #[test]
    fn if_else_branches() {
        let env = Env::new();
        let e = Expr::if_else(
            Expr::prim(PrimOp::Lt, vec![Expr::lit(1.0), Expr::lit(2.0)]),
            Expr::lit("yes"),
            Expr::lit("no"),
        );
        assert_eq!(run(&e, &env).unwrap(), Value::Str("yes".into()));
    }

    #[test]
    fn sum_mean_sqrt_concat() {
        let env = Env::new();
        let list = Expr::list(vec![Expr::lit(1.0), Expr::lit(2.0), Expr::lit(3.0)]);
        assert_eq!(
            run(&Expr::prim(PrimOp::Sum, vec![list.clone()]), &env).unwrap(),
            Value::F64(6.0)
        );
        assert_eq!(run(&Expr::prim(PrimOp::Mean, vec![list]), &env).unwrap(), Value::F64(2.0));
        assert_eq!(
            run(&Expr::prim(PrimOp::Sqrt, vec![Expr::lit(9.0)]), &env).unwrap(),
            Value::F64(3.0)
        );
        let c = Expr::prim(PrimOp::Concat, vec![Expr::lit("n="), Expr::lit(3i64)]);
        assert_eq!(run(&c, &env).unwrap(), Value::Str("n=3".into()));
    }

    #[test]
    fn kernel_call_without_runtime_errors_cleanly() {
        let env = Env::new();
        let e = Expr::call("slow_fcn", vec![Expr::lit(1.0)]);
        let err = run(&e, &env).unwrap_err();
        assert!(err.message.contains("slow_fcn"));
    }

    #[test]
    fn cancelled_cell_aborts_map_chunk_with_sentinel() {
        let env = Env::new();
        let cell = crate::liveness::TaskLiveness::new();
        cell.cancel();
        let chunk = Expr::map_chunk(
            "x",
            Arc::new(Expr::var("x")),
            (0..3i64).map(Value::I64).collect(),
            0,
        );
        let mut buf = CaptureBuffer::new();
        let mut ctx = EvalCtx {
            buffer: &mut buf,
            rng: RngCtx::new(Some(1), 0),
            kernels: None,
            on_immediate: None,
            liveness: Some(Arc::clone(&cell)),
            on_tick: None,
        };
        let err = evaluate(&chunk, &env, &mut ctx).unwrap_err();
        assert_eq!(err.message, crate::liveness::WORKER_CANCEL_ERROR);
    }

    #[test]
    fn map_chunk_ticks_progress_epoch_per_element() {
        let env = Env::new();
        let cell = crate::liveness::TaskLiveness::new();
        let chunk = Expr::map_chunk(
            "x",
            Arc::new(Expr::var("x")),
            (0..4i64).map(Value::I64).collect(),
            0,
        );
        let mut ticks = 0u32;
        let mut on_tick = || ticks += 1;
        let mut buf = CaptureBuffer::new();
        let mut ctx = EvalCtx {
            buffer: &mut buf,
            rng: RngCtx::new(Some(1), 0),
            kernels: None,
            on_immediate: None,
            liveness: Some(Arc::clone(&cell)),
            on_tick: Some(&mut on_tick),
        };
        evaluate(&chunk, &env, &mut ctx).unwrap();
        assert_eq!(cell.epoch(), 4, "one epoch bump per element");
        assert_eq!(ticks, 4, "one worker tick per element");
    }

    #[test]
    fn chaos_hang_marker_skips_and_cancel_interrupts() {
        let env = Env::new();
        // Marker already present: no hang, evaluates to 0 immediately.
        let m = std::env::temp_dir().join(format!("rustures-hang-{}", crate::util::uuid_v4()));
        let marker = m.to_str().unwrap().to_string();
        std::fs::write(&m, b"hung").unwrap();
        let t0 = std::time::Instant::now();
        let v = run(&Expr::chaos_hang_once(5_000, &marker), &env).unwrap();
        assert_eq!(v, Value::I64(0));
        assert!(t0.elapsed() < std::time::Duration::from_millis(1_000));
        let _ = std::fs::remove_file(&m);
        // Pre-cancelled cell: the hang aborts with the cancel sentinel.
        let cell = crate::liveness::TaskLiveness::new();
        cell.cancel();
        let mut buf = CaptureBuffer::new();
        let mut ctx = EvalCtx {
            buffer: &mut buf,
            rng: RngCtx::new(Some(1), 0),
            kernels: None,
            on_immediate: None,
            liveness: Some(cell),
            on_tick: None,
        };
        let err = evaluate(&Expr::chaos_hang(60_000), &env, &mut ctx).unwrap_err();
        assert_eq!(err.message, crate::liveness::WORKER_CANCEL_ERROR);
    }
}
