//! Worker runtime: execute one task ([`execute_task`]) and the remote-worker
//! event loop ([`run_worker`]) used by the multiprocess, cluster, and batch
//! backends.
//!
//! Evaluation here is deterministic in the task frame: the same `TaskSpec`
//! (expression, globals, seed + stream selection) produces the same
//! `TaskResult` on every backend — PR 1's substream rule makes that hold
//! even for RNG draws.  That determinism is what licenses the result cache
//! ([`crate::cache`]): a published result frame can stand in for
//! re-executing the task anywhere, and a cache hit is observationally
//! identical to a fresh evaluation.

pub mod eval;

use std::io::{Read, Write};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::api::conditions::{CaptureBuffer, Condition};
use crate::api::error::FutureError;
use crate::ipc::frame::{read_frame, write_message};
use crate::ipc::intern::InternCache;
use crate::ipc::wire;
use crate::ipc::{Message, TaskMetrics, TaskOutcome, TaskResult, TaskSpec, PROTOCOL_VERSION};
use crate::runtime::RuntimeHandle;
use crate::util::uuid_v4;
use crate::worker::eval::{evaluate, EvalCtx, RngCtx};

fn now_ns() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_nanos() as u64
}

/// Execute one task to completion, capturing output/conditions and timings.
///
/// `on_immediate` is the live-relay hook: called for each
/// `immediateCondition` as it is signaled (backends without live transport
/// pass `None` and the conditions ride home with the result).
pub fn execute_task(
    task: &TaskSpec,
    kernels: Option<RuntimeHandle>,
    on_immediate: Option<&mut dyn FnMut(&Condition)>,
) -> TaskResult {
    execute_task_live(task, kernels, on_immediate, None, None)
}

/// [`execute_task`] plus the liveness plane: an optional in-process
/// progress/cancel cell and an optional per-yield-point tick hook (the
/// remote worker loop uses the hook to emit heartbeat frames).
pub fn execute_task_live(
    task: &TaskSpec,
    kernels: Option<RuntimeHandle>,
    mut on_immediate: Option<&mut dyn FnMut(&Condition)>,
    liveness: Option<std::sync::Arc<crate::liveness::TaskLiveness>>,
    mut on_tick: Option<&mut dyn FnMut()>,
) -> TaskResult {
    let mut buffer = CaptureBuffer::new();
    let started_ns = now_ns();
    let rng = RngCtx::new(task.opts.seed, task.opts.stream_index);
    let outcome = {
        let hook: Option<&mut dyn FnMut(&Condition)> = match &mut on_immediate {
            Some(f) => Some(&mut **f),
            None => None,
        };
        let tick: Option<&mut dyn FnMut()> = match &mut on_tick {
            Some(f) => Some(&mut **f),
            None => None,
        };
        let mut ctx = EvalCtx {
            buffer: &mut buffer,
            rng,
            kernels,
            on_immediate: hook,
            liveness,
            on_tick: tick,
        };
        match evaluate(&task.expr, &task.globals, &mut ctx) {
            Ok(v) => TaskOutcome::Ok(v),
            Err(e) => TaskOutcome::Err(e),
        }
    };
    let finished_ns = now_ns();
    let mut captured = buffer.finish();
    if !task.opts.capture_stdout {
        captured.stdout.clear();
    }
    if !task.opts.capture_conditions {
        captured.conditions.clear();
    }
    TaskResult {
        id: task.id.clone(),
        outcome,
        captured,
        metrics: TaskMetrics { started_ns, finished_ns },
        // Echo the attempt epoch so the coordinator can fence stale frames.
        attempt: task.opts.attempt,
    }
}

/// The remote-worker event loop: read [`Message::Task`]s, execute, stream
/// [`Message::Immediate`]s live, reply with [`Message::Result`]s, until
/// `Shutdown` or EOF.
///
/// Generic over the transport: child-process stdio (multisession), TCP
/// (cluster).  The batch backend uses [`run_batch_job`] instead.
///
/// Chaos: when [`crate::backend::supervisor::MIDWRITE_ENV`] points at a
/// marker path *and* this process is a disposable worker, the first result
/// frame is written only **halfway** before the process exits like a crash
/// (marker file = exactly once) — the coordinator's reader observes a
/// truncated frame, the kill-during-serialization failure mode.
pub fn run_worker<R: Read, W: Write>(
    mut reader: R,
    mut writer: W,
    kernels: Option<RuntimeHandle>,
) -> Result<(), FutureError> {
    let worker_id = uuid_v4();
    let midwrite = std::env::var(crate::backend::supervisor::MIDWRITE_ENV).ok();
    // Protocol-v6 intern cache: task frames install provided blobs here and
    // reference-only frames resolve through it (with NeedBlob recovery on a
    // miss — see read_worker_message).
    let cache = InternCache::new();
    // Wire-v7 `Forward` frames (pipelined dependency outcomes) can arrive
    // interleaved with anything — even mid-NeedBlob-recovery.  They are
    // stashed here and consumed by the pending-collection loop below.
    let mut stash: Vec<Message> = Vec::new();
    write_message(&mut writer, &Message::Hello { worker_id, version: PROTOCOL_VERSION })?;
    loop {
        let msg = if stash.is_empty() {
            read_worker_message(&mut reader, &mut writer, &cache, &mut stash)?
        } else {
            Some(stash.remove(0))
        };
        match msg {
            None | Some(Message::Shutdown) => return Ok(()),
            Some(Message::Ping) => write_message(&mut writer, &Message::Pong)?,
            Some(Message::Task(mut task)) => {
                // Promise pipelining: a task declaring pending dependency
                // ids blocks here until every declared outcome has arrived
                // as a Forward frame, binding each under its reserved
                // sentinel key ([`Expr::Await`] reads them during eval).
                // The coordinator arms this seat's stall deadline only
                // after the last forward, so waiting here is never
                // mistaken for a hang.
                if !task.opts.pending.is_empty() {
                    let mut want: std::collections::HashSet<String> =
                        task.opts.pending.iter().cloned().collect();
                    // Creation-time prebinds satisfy their ids up front.
                    want.retain(|id| {
                        !task.globals.contains(&crate::ipc::pipeline_ok_key(id))
                            && !task.globals.contains(&crate::ipc::pipeline_err_key(id))
                    });
                    let mut cancelled = false;
                    while !want.is_empty() {
                        let msg = if stash.is_empty() {
                            read_worker_message(&mut reader, &mut writer, &cache, &mut stash)?
                        } else {
                            Some(stash.remove(0))
                        };
                        match msg {
                            None | Some(Message::Shutdown) => return Ok(()),
                            Some(Message::Ping) => {
                                write_message(&mut writer, &Message::Pong)?
                            }
                            Some(Message::Forward { future_id, outcome }) => {
                                want.remove(&future_id);
                                bind_forward(&mut task.globals, &future_id, outcome);
                            }
                            Some(Message::Cancel { task_id }) if task_id == task.id => {
                                cancelled = true;
                                break;
                            }
                            Some(Message::Cancel { .. }) => {}
                            Some(Message::NeedBlob { .. }) | Some(Message::Blob { .. }) => {}
                            Some(other) => {
                                return Err(FutureError::Channel(format!(
                                    "unexpected message while awaiting forwards: {other:?}"
                                )));
                            }
                        }
                    }
                    if cancelled {
                        continue;
                    }
                }
                // Nested futures created while evaluating this task follow
                // the serialized session context the coordinator shipped:
                // topology tail (empty ⇒ sequential — the nested-parallelism
                // protection) PLUS the originating session's plan-wide
                // retry default and counter base.
                //
                // Both the immediate relay and the heartbeat tick write to
                // the same transport from inside the evaluator, so the
                // writer lives in a `RefCell` the two closures share — no
                // per-worker heartbeat thread exists, beats ride the
                // evaluator's yield points.
                let send_err = std::cell::RefCell::new(None);
                let writer_cell = std::cell::RefCell::new(&mut writer);
                // Per-session liveness rides in the task's context; the
                // process-global config is only the fallback for contexts
                // predating it (heartbeat_ms == 0).
                let hb_interval = if task.opts.context.heartbeat_ms > 0 {
                    std::time::Duration::from_millis(task.opts.context.heartbeat_ms)
                } else {
                    crate::liveness::liveness_config().heartbeat_interval
                };
                let mut last_beat = std::time::Instant::now();
                let result = crate::api::session::scope_task_context(&task.opts.context, || {
                    let mut on_imm = |c: &Condition| {
                        let msg =
                            Message::Immediate { task_id: task.id.clone(), condition: c.clone() };
                        if let Err(e) = write_message(&mut *writer_cell.borrow_mut(), &msg) {
                            *send_err.borrow_mut() = Some(e);
                        }
                    };
                    let mut on_tick = || {
                        if last_beat.elapsed() < hb_interval {
                            return;
                        }
                        let msg = Message::Heartbeat { task_id: task.id.clone() };
                        match write_message(&mut *writer_cell.borrow_mut(), &msg) {
                            Ok(()) => last_beat = std::time::Instant::now(),
                            Err(e) => *send_err.borrow_mut() = Some(e),
                        }
                    };
                    execute_task_live(
                        &task,
                        kernels.clone(),
                        Some(&mut on_imm),
                        None,
                        Some(&mut on_tick),
                    )
                });
                if let Some(e) = send_err.into_inner() {
                    return Err(e);
                }
                if let Some(marker) = &midwrite {
                    maybe_die_mid_write(marker, &mut writer, &result);
                }
                write_message(&mut writer, &Message::Result(result))?;
            }
            // A cancel for a task we are *not* currently running (it already
            // finished, or was never dispatched here) is a no-op; a
            // single-threaded worker cannot observe one mid-evaluation —
            // the coordinator's seat kill is the enforcement path there.
            Some(Message::Cancel { .. }) => {}
            // A stray Blob (answering a NeedBlob that already resolved) or
            // a NeedBlob echoed back at us is dropped, not fatal.
            Some(Message::NeedBlob { .. }) | Some(Message::Blob { .. }) => {}
            // A Forward with no task collecting it: the consumer was
            // cancelled between frames (or the coordinator retransmitted).
            Some(Message::Forward { .. }) => {}
            Some(other) => {
                return Err(FutureError::Channel(format!(
                    "worker received unexpected message: {other:?}"
                )));
            }
        }
    }
}

/// Bind a forwarded (or prebound) pipelined-dependency outcome into a
/// task's globals under the reserved sentinel key the worker-side
/// [`crate::api::expr::Expr::Await`] evaluation reads.
fn bind_forward(globals: &mut crate::api::env::Env, future_id: &str, outcome: TaskOutcome) {
    match outcome {
        TaskOutcome::Ok(v) => {
            globals.insert(&crate::ipc::pipeline_ok_key(future_id), v);
        }
        TaskOutcome::Err(e) => {
            globals.insert(
                &crate::ipc::pipeline_err_key(future_id),
                crate::api::value::Value::Str(e.message),
            );
        }
    }
}

/// Read and decode one frame against the worker's intern cache, running
/// the `NeedBlob` recovery protocol on a miss: ask the coordinator for the
/// missing blob, install the answer, and retry the decode.  The mirror
/// drift this recovers from (coordinator ledger vs. worker cache) is
/// bounded, so recovery is capped — a non-converging frame is a channel
/// error, never a hang or a wrong result.  `Forward` frames that arrive
/// mid-recovery (the coordinator flushes pipelined outcomes right behind
/// the task frame) are pushed onto `stash` for the caller, preserving
/// arrival order.
fn read_worker_message<R: Read, W: Write>(
    reader: &mut R,
    writer: &mut W,
    cache: &InternCache,
    stash: &mut Vec<Message>,
) -> Result<Option<Message>, FutureError> {
    let frame = match read_frame(reader)? {
        None => return Ok(None),
        Some(f) => f,
    };
    let mut recoveries = 0;
    loop {
        let missing = match wire::decode_frame_body(frame.kind, frame.codec, &frame.body, Some(cache))
        {
            Ok(m) => return Ok(Some(m)),
            Err(e) => match e.kind {
                wire::WireErrorKind::MissingBlob { digest } => digest,
                _ => return Err(FutureError::Channel(format!("bad frame: {e}"))),
            },
        };
        recoveries += 1;
        if recoveries > 64 {
            return Err(FutureError::Channel(format!(
                "intern recovery did not converge after {recoveries} round trips"
            )));
        }
        write_message(writer, &Message::NeedBlob { digests: vec![missing] })?;
        // Block until the Blob answer lands, servicing control frames that
        // arrive in between.
        loop {
            let f2 = match read_frame(reader)? {
                None => return Ok(None),
                Some(f2) => f2,
            };
            match wire::decode_frame_body(f2.kind, f2.codec, &f2.body, Some(cache)) {
                Ok(Message::Blob { digest, bytes }) => {
                    let Some(bytes) = bytes else {
                        // The coordinator's store evicted the blob: fail
                        // closed — the supervisor retries via a fresh seat
                        // whose ledger re-provides everything.
                        return Err(FutureError::Channel(format!(
                            "coordinator no longer holds interned blob {digest}"
                        )));
                    };
                    let blob = wire::decode_blob(&bytes)
                        .map_err(|e| FutureError::Channel(format!("bad blob frame: {e}")))?;
                    cache.insert(digest, blob);
                    break; // retry the original frame
                }
                Ok(Message::Shutdown) => return Ok(Some(Message::Shutdown)),
                Ok(Message::Ping) => write_message(writer, &Message::Pong)?,
                Ok(Message::Cancel { .. }) => {}
                Ok(fwd @ Message::Forward { .. }) => stash.push(fwd),
                Ok(other) => {
                    return Err(FutureError::Channel(format!(
                        "unexpected frame during intern recovery: {other:?}"
                    )))
                }
                Err(e) => return Err(FutureError::Channel(format!("bad frame: {e}"))),
            }
        }
    }
}

/// The kill-during-serialization chaos probe: write only HALF the encoded
/// result frame, flush, and exit like a crash.  Gated on
/// [`crate::backend::supervisor::kill_exits_process`] so an in-process
/// `run_worker` (tests over in-memory pipes) can never take the test
/// runner down; the marker file makes it fire exactly once per path.
fn maybe_die_mid_write<W: Write>(marker: &str, writer: &mut W, result: &TaskResult) {
    if !crate::backend::supervisor::kill_exits_process() {
        return;
    }
    // Atomic claim of the marker (create_new): exactly ONE worker process
    // fires, even when several finish their first frames simultaneously —
    // a bare exists-then-write check would let two workers race past it.
    // Losing the race (file exists) means the kill already fired: write
    // the result normally.  The marker lands BEFORE dying so the retried
    // run survives.
    match std::fs::OpenOptions::new().write(true).create_new(true).open(marker) {
        Ok(mut f) => {
            let _ = f.write_all(b"killed-mid-write");
        }
        Err(_) => return,
    }
    let frame = crate::ipc::wire::encode_message(&Message::Result(result.clone()));
    let half = frame.len() / 2;
    let _ = writer.write_all(&frame[..half]);
    let _ = writer.flush();
    std::process::exit(137);
}

/// Batch-mode execution: read a task file, write a result file (the
/// `batchtools` job model — no live channel, so immediates ride with the
/// result).
pub fn run_batch_job(
    task_path: &std::path::Path,
    result_path: &std::path::Path,
    kernels: Option<RuntimeHandle>,
) -> Result<(), FutureError> {
    let bytes = std::fs::read(task_path)
        .map_err(|e| FutureError::Channel(format!("read {}: {e}", task_path.display())))?;
    let msg = crate::ipc::wire::decode_message(&bytes)
        .map_err(|e| FutureError::Channel(format!("bad task file: {e}")))?;
    let task = match msg {
        Message::Task(t) => t,
        other => {
            return Err(FutureError::Channel(format!("task file held {other:?}")));
        }
    };
    // Same context install as run_worker: nested futures inherit the
    // shipped topology tail + retry default.
    let result =
        crate::api::session::scope_task_context(&task.opts.context, || {
            execute_task(&task, kernels, None)
        });
    let encoded = crate::ipc::wire::encode_message(&Message::Result(result));
    // Write-then-rename: the scheduler polls for the final name, so it never
    // observes a partial file.
    let tmp = result_path.with_extension("tmp");
    std::fs::write(&tmp, &encoded)
        .map_err(|e| FutureError::Channel(format!("write {}: {e}", tmp.display())))?;
    std::fs::rename(&tmp, result_path)
        .map_err(|e| FutureError::Channel(format!("rename result: {e}")))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::env::Env;
    use crate::api::expr::Expr;
    use crate::ipc::frame::read_message;
    use crate::ipc::TaskOpts;

    fn task(expr: Expr) -> TaskSpec {
        TaskSpec { id: uuid_v4(), expr, globals: Env::new(), opts: TaskOpts::default() }
    }

    #[test]
    fn execute_task_success_with_capture() {
        let t = task(Expr::seq(vec![Expr::cat(Expr::lit("hi\n")), Expr::lit(5i64)]));
        let r = execute_task(&t, None, None);
        assert_eq!(r.outcome, TaskOutcome::Ok(crate::api::value::Value::I64(5)));
        assert_eq!(r.captured.stdout, "hi\n");
        assert!(r.metrics.finished_ns >= r.metrics.started_ns);
    }

    #[test]
    fn execute_task_error_is_captured_not_propagated() {
        let t = task(Expr::stop(Expr::lit("bad")));
        let r = execute_task(&t, None, None);
        match r.outcome {
            TaskOutcome::Err(e) => assert_eq!(e.message, "bad"),
            _ => panic!("expected error outcome"),
        }
    }

    #[test]
    fn capture_opt_outs_clear_payloads() {
        let mut t = task(Expr::seq(vec![
            Expr::cat(Expr::lit("noise")),
            Expr::warning(Expr::lit("w")),
            Expr::lit(1i64),
        ]));
        t.opts.capture_stdout = false;
        t.opts.capture_conditions = false;
        let r = execute_task(&t, None, None);
        assert!(r.captured.stdout.is_empty());
        assert!(r.captured.conditions.is_empty());
    }

    #[test]
    fn immediate_hook_fires_during_eval() {
        let t = task(Expr::seq(vec![
            Expr::progress(Expr::lit("10%")),
            Expr::progress(Expr::lit("90%")),
            Expr::lit(0i64),
        ]));
        let mut seen = Vec::new();
        let mut hook = |c: &Condition| seen.push(c.message.clone());
        let _ = execute_task(&t, None, Some(&mut hook));
        assert_eq!(seen, vec!["10%", "90%"]);
    }

    #[test]
    fn worker_loop_over_in_memory_pipes() {
        use std::io::Cursor;
        // Coordinator side: one task, then shutdown.
        let t = task(Expr::add(Expr::lit(1i64), Expr::lit(2i64)));
        let mut input = Vec::new();
        write_message(&mut input, &Message::Task(t.clone())).unwrap();
        write_message(&mut input, &Message::Shutdown).unwrap();

        let mut output = Vec::new();
        run_worker(Cursor::new(input), &mut output, None).unwrap();

        let mut cur = Cursor::new(output);
        let hello = read_message(&mut cur).unwrap().unwrap();
        assert!(matches!(hello, Message::Hello { .. }));
        match read_message(&mut cur).unwrap().unwrap() {
            Message::Result(r) => {
                assert_eq!(r.id, t.id);
                assert_eq!(r.outcome, TaskOutcome::Ok(crate::api::value::Value::I64(3)));
            }
            other => panic!("expected result, got {other:?}"),
        }
        assert_eq!(read_message(&mut cur).unwrap(), None);
    }

    #[test]
    fn worker_intern_recovery_via_need_blob() {
        use crate::api::value::{Tensor, Value};
        use crate::ipc::intern::{digest_bytes, digest_value, SeatLedger};
        use std::io::Cursor;

        let big = Value::Tensor(Tensor::zeros(&[1024]));
        let mut globals = Env::new();
        globals.insert("g", big.clone());
        let body = std::sync::Arc::new(Expr::seq(vec![
            Expr::lit(Value::Tensor(Tensor::zeros(&[600]))),
            Expr::var("g"),
        ]));
        let t = TaskSpec {
            id: uuid_v4(),
            expr: Expr::map_chunk("x", std::sync::Arc::clone(&body), vec![Value::I64(0)], 0),
            globals,
            opts: TaskOpts::default(),
        };
        let mut ledger = SeatLedger::new();
        // Burn the provides against an earlier frame so the frame under
        // test is reference-only — the respawned-worker scenario, where
        // the coordinator's ledger says "sent" but the cache is empty.
        let _first = wire::encode_task_message_interned(&t, &mut ledger);
        let second = wire::encode_task_message_interned(&t, &mut ledger);

        let body_blob = wire::expr_blob_bytes(&body);
        let body_digest = digest_bytes(&body_blob);
        let value_digest = digest_value(&big);
        let value_blob = wire::value_blob_bytes(&big);

        // Pre-stage the Blob answers in decode order: the MapChunk body
        // reference misses first, then the captured global.
        let mut input = second;
        for (dg, blob) in [(body_digest, body_blob), (value_digest, value_blob)] {
            input.extend_from_slice(&wire::encode_message(&Message::Blob {
                digest: dg,
                bytes: Some(blob),
            }));
        }
        let mut output = Vec::new();
        let cache = InternCache::new();
        let mut stash = Vec::new();
        let msg = read_worker_message(&mut Cursor::new(input), &mut output, &cache, &mut stash)
            .unwrap()
            .unwrap();
        assert_eq!(msg, Message::Task(t));
        // The worker asked for exactly the two blobs, in decode order.
        let mut cur = Cursor::new(output);
        match read_message(&mut cur).unwrap().unwrap() {
            Message::NeedBlob { digests } => assert_eq!(digests, vec![body_digest]),
            other => panic!("{other:?}"),
        }
        match read_message(&mut cur).unwrap().unwrap() {
            Message::NeedBlob { digests } => assert_eq!(digests, vec![value_digest]),
            other => panic!("{other:?}"),
        }
        assert_eq!(read_message(&mut cur).unwrap(), None);
    }

    #[test]
    fn batch_job_roundtrip_via_files() {
        let dir = std::env::temp_dir().join(format!("rustures-test-{}", uuid_v4()));
        std::fs::create_dir_all(&dir).unwrap();
        let task_path = dir.join("job.task");
        let result_path = dir.join("job.result");

        let t = task(Expr::mul(Expr::lit(6i64), Expr::lit(7i64)));
        std::fs::write(&task_path, crate::ipc::wire::encode_message(&Message::Task(t.clone())))
            .unwrap();
        run_batch_job(&task_path, &result_path, None).unwrap();

        let bytes = std::fs::read(&result_path).unwrap();
        match crate::ipc::wire::decode_message(&bytes).unwrap() {
            Message::Result(r) => {
                assert_eq!(r.outcome, TaskOutcome::Ok(crate::api::value::Value::I64(42)))
            }
            other => panic!("{other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
