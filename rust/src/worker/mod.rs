//! Worker runtime: execute one task ([`execute_task`]) and the remote-worker
//! event loop ([`run_worker`]) used by the multiprocess, cluster, and batch
//! backends.

pub mod eval;

use std::io::{Read, Write};
use std::time::{SystemTime, UNIX_EPOCH};

use crate::api::conditions::{CaptureBuffer, Condition};
use crate::api::error::FutureError;
use crate::ipc::frame::{read_message, write_message};
use crate::ipc::{Message, TaskMetrics, TaskOutcome, TaskResult, TaskSpec, PROTOCOL_VERSION};
use crate::runtime::RuntimeHandle;
use crate::util::uuid_v4;
use crate::worker::eval::{evaluate, EvalCtx, RngCtx};

fn now_ns() -> u64 {
    SystemTime::now().duration_since(UNIX_EPOCH).unwrap_or_default().as_nanos() as u64
}

/// Execute one task to completion, capturing output/conditions and timings.
///
/// `on_immediate` is the live-relay hook: called for each
/// `immediateCondition` as it is signaled (backends without live transport
/// pass `None` and the conditions ride home with the result).
pub fn execute_task(
    task: &TaskSpec,
    kernels: Option<RuntimeHandle>,
    on_immediate: Option<&mut dyn FnMut(&Condition)>,
) -> TaskResult {
    execute_task_live(task, kernels, on_immediate, None, None)
}

/// [`execute_task`] plus the liveness plane: an optional in-process
/// progress/cancel cell and an optional per-yield-point tick hook (the
/// remote worker loop uses the hook to emit heartbeat frames).
pub fn execute_task_live(
    task: &TaskSpec,
    kernels: Option<RuntimeHandle>,
    mut on_immediate: Option<&mut dyn FnMut(&Condition)>,
    liveness: Option<std::sync::Arc<crate::liveness::TaskLiveness>>,
    mut on_tick: Option<&mut dyn FnMut()>,
) -> TaskResult {
    let mut buffer = CaptureBuffer::new();
    let started_ns = now_ns();
    let rng = RngCtx::new(task.opts.seed, task.opts.stream_index);
    let outcome = {
        let hook: Option<&mut dyn FnMut(&Condition)> = match &mut on_immediate {
            Some(f) => Some(&mut **f),
            None => None,
        };
        let tick: Option<&mut dyn FnMut()> = match &mut on_tick {
            Some(f) => Some(&mut **f),
            None => None,
        };
        let mut ctx = EvalCtx {
            buffer: &mut buffer,
            rng,
            kernels,
            on_immediate: hook,
            liveness,
            on_tick: tick,
        };
        match evaluate(&task.expr, &task.globals, &mut ctx) {
            Ok(v) => TaskOutcome::Ok(v),
            Err(e) => TaskOutcome::Err(e),
        }
    };
    let finished_ns = now_ns();
    let mut captured = buffer.finish();
    if !task.opts.capture_stdout {
        captured.stdout.clear();
    }
    if !task.opts.capture_conditions {
        captured.conditions.clear();
    }
    TaskResult {
        id: task.id.clone(),
        outcome,
        captured,
        metrics: TaskMetrics { started_ns, finished_ns },
        // Echo the attempt epoch so the coordinator can fence stale frames.
        attempt: task.opts.attempt,
    }
}

/// The remote-worker event loop: read [`Message::Task`]s, execute, stream
/// [`Message::Immediate`]s live, reply with [`Message::Result`]s, until
/// `Shutdown` or EOF.
///
/// Generic over the transport: child-process stdio (multisession), TCP
/// (cluster).  The batch backend uses [`run_batch_job`] instead.
///
/// Chaos: when [`crate::backend::supervisor::MIDWRITE_ENV`] points at a
/// marker path *and* this process is a disposable worker, the first result
/// frame is written only **halfway** before the process exits like a crash
/// (marker file = exactly once) — the coordinator's reader observes a
/// truncated frame, the kill-during-serialization failure mode.
pub fn run_worker<R: Read, W: Write>(
    mut reader: R,
    mut writer: W,
    kernels: Option<RuntimeHandle>,
) -> Result<(), FutureError> {
    let worker_id = uuid_v4();
    let midwrite = std::env::var(crate::backend::supervisor::MIDWRITE_ENV).ok();
    write_message(&mut writer, &Message::Hello { worker_id, version: PROTOCOL_VERSION })?;
    loop {
        match read_message(&mut reader)? {
            None | Some(Message::Shutdown) => return Ok(()),
            Some(Message::Ping) => write_message(&mut writer, &Message::Pong)?,
            Some(Message::Task(task)) => {
                // Nested futures created while evaluating this task follow
                // the serialized session context the coordinator shipped:
                // topology tail (empty ⇒ sequential — the nested-parallelism
                // protection) PLUS the originating session's plan-wide
                // retry default and counter base.
                //
                // Both the immediate relay and the heartbeat tick write to
                // the same transport from inside the evaluator, so the
                // writer lives in a `RefCell` the two closures share — no
                // per-worker heartbeat thread exists, beats ride the
                // evaluator's yield points.
                let send_err = std::cell::RefCell::new(None);
                let writer_cell = std::cell::RefCell::new(&mut writer);
                let hb_interval = crate::liveness::liveness_config().heartbeat_interval;
                let mut last_beat = std::time::Instant::now();
                let result = crate::api::session::scope_task_context(&task.opts.context, || {
                    let mut on_imm = |c: &Condition| {
                        let msg =
                            Message::Immediate { task_id: task.id.clone(), condition: c.clone() };
                        if let Err(e) = write_message(&mut *writer_cell.borrow_mut(), &msg) {
                            *send_err.borrow_mut() = Some(e);
                        }
                    };
                    let mut on_tick = || {
                        if last_beat.elapsed() < hb_interval {
                            return;
                        }
                        let msg = Message::Heartbeat { task_id: task.id.clone() };
                        match write_message(&mut *writer_cell.borrow_mut(), &msg) {
                            Ok(()) => last_beat = std::time::Instant::now(),
                            Err(e) => *send_err.borrow_mut() = Some(e),
                        }
                    };
                    execute_task_live(
                        &task,
                        kernels.clone(),
                        Some(&mut on_imm),
                        None,
                        Some(&mut on_tick),
                    )
                });
                if let Some(e) = send_err.into_inner() {
                    return Err(e);
                }
                if let Some(marker) = &midwrite {
                    maybe_die_mid_write(marker, &mut writer, &result);
                }
                write_message(&mut writer, &Message::Result(result))?;
            }
            // A cancel for a task we are *not* currently running (it already
            // finished, or was never dispatched here) is a no-op; a
            // single-threaded worker cannot observe one mid-evaluation —
            // the coordinator's seat kill is the enforcement path there.
            Some(Message::Cancel { .. }) => {}
            Some(other) => {
                return Err(FutureError::Channel(format!(
                    "worker received unexpected message: {other:?}"
                )));
            }
        }
    }
}

/// The kill-during-serialization chaos probe: write the length prefix and
/// only HALF the result payload, flush, and exit like a crash.  Gated on
/// [`crate::backend::supervisor::kill_exits_process`] so an in-process
/// `run_worker` (tests over in-memory pipes) can never take the test
/// runner down; the marker file makes it fire exactly once per path.
fn maybe_die_mid_write<W: Write>(marker: &str, writer: &mut W, result: &TaskResult) {
    if !crate::backend::supervisor::kill_exits_process() {
        return;
    }
    // Atomic claim of the marker (create_new): exactly ONE worker process
    // fires, even when several finish their first frames simultaneously —
    // a bare exists-then-write check would let two workers race past it.
    // Losing the race (file exists) means the kill already fired: write
    // the result normally.  The marker lands BEFORE dying so the retried
    // run survives.
    match std::fs::OpenOptions::new().write(true).create_new(true).open(marker) {
        Ok(mut f) => {
            let _ = f.write_all(b"killed-mid-write");
        }
        Err(_) => return,
    }
    let payload = crate::ipc::wire::encode_message(&Message::Result(result.clone()));
    let len = payload.len() as u32;
    let half = payload.len() / 2;
    let _ = writer.write_all(&len.to_le_bytes());
    let _ = writer.write_all(&payload[..half]);
    let _ = writer.flush();
    std::process::exit(137);
}

/// Batch-mode execution: read a task file, write a result file (the
/// `batchtools` job model — no live channel, so immediates ride with the
/// result).
pub fn run_batch_job(
    task_path: &std::path::Path,
    result_path: &std::path::Path,
    kernels: Option<RuntimeHandle>,
) -> Result<(), FutureError> {
    let bytes = std::fs::read(task_path)
        .map_err(|e| FutureError::Channel(format!("read {}: {e}", task_path.display())))?;
    let msg = crate::ipc::wire::decode_message(&bytes)
        .map_err(|e| FutureError::Channel(format!("bad task file: {e}")))?;
    let task = match msg {
        Message::Task(t) => t,
        other => {
            return Err(FutureError::Channel(format!("task file held {other:?}")));
        }
    };
    // Same context install as run_worker: nested futures inherit the
    // shipped topology tail + retry default.
    let result =
        crate::api::session::scope_task_context(&task.opts.context, || {
            execute_task(&task, kernels, None)
        });
    let encoded = crate::ipc::wire::encode_message(&Message::Result(result));
    // Write-then-rename: the scheduler polls for the final name, so it never
    // observes a partial file.
    let tmp = result_path.with_extension("tmp");
    std::fs::write(&tmp, &encoded)
        .map_err(|e| FutureError::Channel(format!("write {}: {e}", tmp.display())))?;
    std::fs::rename(&tmp, result_path)
        .map_err(|e| FutureError::Channel(format!("rename result: {e}")))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::env::Env;
    use crate::api::expr::Expr;
    use crate::ipc::TaskOpts;

    fn task(expr: Expr) -> TaskSpec {
        TaskSpec { id: uuid_v4(), expr, globals: Env::new(), opts: TaskOpts::default() }
    }

    #[test]
    fn execute_task_success_with_capture() {
        let t = task(Expr::seq(vec![Expr::cat(Expr::lit("hi\n")), Expr::lit(5i64)]));
        let r = execute_task(&t, None, None);
        assert_eq!(r.outcome, TaskOutcome::Ok(crate::api::value::Value::I64(5)));
        assert_eq!(r.captured.stdout, "hi\n");
        assert!(r.metrics.finished_ns >= r.metrics.started_ns);
    }

    #[test]
    fn execute_task_error_is_captured_not_propagated() {
        let t = task(Expr::stop(Expr::lit("bad")));
        let r = execute_task(&t, None, None);
        match r.outcome {
            TaskOutcome::Err(e) => assert_eq!(e.message, "bad"),
            _ => panic!("expected error outcome"),
        }
    }

    #[test]
    fn capture_opt_outs_clear_payloads() {
        let mut t = task(Expr::seq(vec![
            Expr::cat(Expr::lit("noise")),
            Expr::warning(Expr::lit("w")),
            Expr::lit(1i64),
        ]));
        t.opts.capture_stdout = false;
        t.opts.capture_conditions = false;
        let r = execute_task(&t, None, None);
        assert!(r.captured.stdout.is_empty());
        assert!(r.captured.conditions.is_empty());
    }

    #[test]
    fn immediate_hook_fires_during_eval() {
        let t = task(Expr::seq(vec![
            Expr::progress(Expr::lit("10%")),
            Expr::progress(Expr::lit("90%")),
            Expr::lit(0i64),
        ]));
        let mut seen = Vec::new();
        let mut hook = |c: &Condition| seen.push(c.message.clone());
        let _ = execute_task(&t, None, Some(&mut hook));
        assert_eq!(seen, vec!["10%", "90%"]);
    }

    #[test]
    fn worker_loop_over_in_memory_pipes() {
        use std::io::Cursor;
        // Coordinator side: one task, then shutdown.
        let t = task(Expr::add(Expr::lit(1i64), Expr::lit(2i64)));
        let mut input = Vec::new();
        write_message(&mut input, &Message::Task(t.clone())).unwrap();
        write_message(&mut input, &Message::Shutdown).unwrap();

        let mut output = Vec::new();
        run_worker(Cursor::new(input), &mut output, None).unwrap();

        let mut cur = Cursor::new(output);
        let hello = read_message(&mut cur).unwrap().unwrap();
        assert!(matches!(hello, Message::Hello { .. }));
        match read_message(&mut cur).unwrap().unwrap() {
            Message::Result(r) => {
                assert_eq!(r.id, t.id);
                assert_eq!(r.outcome, TaskOutcome::Ok(crate::api::value::Value::I64(3)));
            }
            other => panic!("expected result, got {other:?}"),
        }
        assert_eq!(read_message(&mut cur).unwrap(), None);
    }

    #[test]
    fn batch_job_roundtrip_via_files() {
        let dir = std::env::temp_dir().join(format!("rustures-test-{}", uuid_v4()));
        std::fs::create_dir_all(&dir).unwrap();
        let task_path = dir.join("job.task");
        let result_path = dir.join("job.result");

        let t = task(Expr::mul(Expr::lit(6i64), Expr::lit(7i64)));
        std::fs::write(&task_path, crate::ipc::wire::encode_message(&Message::Task(t.clone())))
            .unwrap();
        run_batch_job(&task_path, &result_path, None).unwrap();

        let bytes = std::fs::read(&result_path).unwrap();
        match crate::ipc::wire::decode_message(&bytes).unwrap() {
            Message::Result(r) => {
                assert_eq!(r.outcome, TaskOutcome::Ok(crate::api::value::Value::I64(42)))
            }
            other => panic!("{other:?}"),
        }
        std::fs::remove_dir_all(&dir).ok();
    }
}
