//! Plan-time static-analysis integration tests: the acceptance gates for
//! the analyzer subsystem.
//!
//! * A Deny-configured lint rejects at creation with structured
//!   diagnostics — no capacity lease, no worker round trip (asserted via
//!   the capacity ledger AND `metrics::capacity_json()`).
//! * An Allow run is bit-identical to a run with analysis disabled.
//! * A Warn run relays the diagnostic through the conditions plane and
//!   counts it in `rustures.analysis.v1` — without perturbing values.
//! * `Session::lint` is a pure probe: full diagnostics, zero side effects.

use std::sync::Mutex;
use std::time::Duration;

use rustures::api::conditions::{set_sink, RecordingSink};
use rustures::api::globals::GlobalsSpec;
use rustures::prelude::*;

/// The condition sink is process-global; tests that install a
/// `RecordingSink` take this lock so parallel test threads cannot steal
/// each other's relayed diagnostics.
static SINK_LOCK: Mutex<()> = Mutex::new(());

/// A future whose single global is a ~16KB tensor — far over a 64-byte
/// budget, far under the 500MiB default.
fn oversized(env: &mut Env) -> Expr {
    env.insert("payload", Tensor::new(vec![64, 64], vec![0.5f32; 4096]).unwrap());
    Expr::prim(PrimOp::Sum, vec![Expr::var("payload")])
}

#[test]
fn deny_rejects_at_creation_with_no_capacity_lease() {
    let s = Session::with_plan(PlanSpec::multicore(2));
    s.set_analysis_config(AnalysisConfig::new().max_globals_size(64));
    let mut env = Env::new();
    let expr = oversized(&mut env);

    let got = s.scope(|_| future(expr, &env));
    let diagnostics = match got {
        Err(FutureError::Rejected { diagnostics }) => diagnostics,
        other => panic!("expected FutureError::Rejected, got {other:?}"),
    };
    assert!(
        diagnostics
            .iter()
            .any(|d| d.code == LintCode::ExportSize && d.severity == Severity::Deny),
        "{diagnostics:?}"
    );

    // The rejection happened before admission: the ledger never saw this
    // session.  Check both the typed API and the JSON metrics surface.
    assert_eq!(rustures::capacity::session_peak_in_use(s.id()), 0);
    let cap = rustures::metrics::capacity_json();
    let doc = rustures::util::json::parse(&cap).expect("valid capacity JSON");
    let sessions = doc.get("sessions").unwrap().as_arr().unwrap();
    assert!(
        !sessions
            .iter()
            .any(|e| e.get("session").and_then(|v| v.as_i64()) == Some(s.id() as i64)),
        "denied session must not appear in the capacity ledger: {cap}"
    );

    // Counted in the analysis metrics surface.
    let counters = rustures::metrics::session_analysis_counters(s.id());
    assert_eq!(counters.denies, 1);
    assert!(counters.codes.iter().any(|(c, n)| c == "export-size" && *n == 1));
    let json = rustures::metrics::analysis_json();
    assert!(json.contains("\"schema\":\"rustures.analysis.v1\""), "{json}");
    assert!(json.contains(&format!("\"session\":{}", s.id())), "{json}");
    s.close();
}

#[test]
fn allow_run_is_bit_identical_to_disabled_analysis() {
    // Seeded draw + payload sum: deterministic, so the two runs compare
    // bit-for-bit.
    let run = |config: AnalysisConfig| -> Value {
        let s = Session::with_plan(PlanSpec::sequential());
        s.set_analysis_config(config);
        let mut env = Env::new();
        env.insert("payload", Tensor::new(vec![64, 64], vec![0.5f32; 4096]).unwrap());
        let expr = Expr::list(vec![
            Expr::prim(PrimOp::Sum, vec![Expr::var("payload")]),
            Expr::runif(4),
        ]);
        let v = s
            .scope(|_| {
                let f = future_with(expr, &env, FutureOpts::new().seed(7)).unwrap();
                f.value().unwrap()
            });
        s.close();
        v
    };
    // Budget of 64 bytes would deny — Allow overrides the severity, so
    // the same over-budget future must run untouched.
    let allowed =
        run(AnalysisConfig::new().max_globals_size(64).allow(LintCode::ExportSize));
    let disabled = run(AnalysisConfig::disabled());
    assert_eq!(allowed, disabled);
}

#[test]
fn warn_is_relayed_and_counted_without_perturbing_the_value() {
    let _sink = SINK_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let s = Session::with_plan(PlanSpec::sequential());
    s.set_analysis_config(
        AnalysisConfig::new().warn(LintCode::ExportSize).max_globals_size(64),
    );
    let mut env = Env::new();
    let expr = oversized(&mut env);

    let rec = RecordingSink::new();
    set_sink(Some(Box::new(rec.clone())));
    let v = s.scope(|_| future(expr, &env).unwrap().value().unwrap());
    set_sink(None);

    assert_eq!(v, Value::F64(4096.0 * 0.5));
    assert!(
        rec.conditions()
            .iter()
            .any(|c| c.kind == ConditionKind::Warning && c.message.contains("export-size")),
        "warn diagnostic must be relayed through the conditions plane: {:?}",
        rec.conditions()
    );
    let counters = rustures::metrics::session_analysis_counters(s.id());
    assert_eq!(counters.warns, 1);
    assert_eq!(counters.denies, 0);
    s.close();
}

#[test]
fn session_lint_probes_without_side_effects() {
    let s = Session::with_plan(PlanSpec::sequential());
    s.set_default_deadline(Some(Duration::from_millis(1)));
    let env = Env::new();
    // Unseeded draws (Allow by default — only lint shows it) plus a
    // deadline below the heartbeat interval (Warn by default).
    let diags = s.scope(|_| s.lint(&Expr::runif(2), &env, &FutureOpts::new()));
    assert!(
        diags.iter().any(|d| d.code == LintCode::UnseededRng && d.severity == Severity::Allow),
        "{diags:?}"
    );
    assert!(diags.iter().any(|d| d.code == LintCode::DeadlineHeartbeat), "{diags:?}");
    // A pure probe: nothing counted, nothing admitted.
    let counters = rustures::metrics::session_analysis_counters(s.id());
    assert_eq!((counters.denies, counters.warns), (0, 0));
    assert_eq!(rustures::capacity::session_peak_in_use(s.id()), 0);
    s.close();
}

#[test]
fn explicit_capture_typo_warns_at_creation_but_still_runs() {
    let _sink = SINK_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let s = Session::with_plan(PlanSpec::sequential());
    let mut env = Env::new();
    env.insert("weights", 2.0f64);
    env.insert("wieghts", 3.0f64); // the typo also exists in the env
    let expr = Expr::mul(Expr::var("weights"), Expr::lit(10.0));
    let opts = FutureOpts::new().globals(GlobalsSpec::Explicit(vec![
        "weights".to_string(),
        "wieghts".to_string(),
    ]));

    let rec = RecordingSink::new();
    set_sink(Some(Box::new(rec.clone())));
    let v = s.scope(|_| future_with(expr, &env, opts).unwrap().value().unwrap());
    set_sink(None);

    assert_eq!(v, Value::F64(20.0));
    assert!(
        rec.conditions().iter().any(|c| c.message.contains("useless-capture")
            && c.message.contains("wieghts")),
        "typo capture must warn at creation: {:?}",
        rec.conditions()
    );
    assert_eq!(rustures::metrics::session_analysis_counters(s.id()).warns, 1);
    s.close();
}

#[test]
fn rejection_cost_is_zero_retries_and_replayable() {
    // A rejected create must not enter the retry path: Rejected is not
    // recoverable, so supervised relaunch loops cannot spin on it.
    let e = FutureError::Rejected {
        diagnostics: vec![Diagnostic {
            code: LintCode::ExportSize,
            severity: Severity::Deny,
            path: "globals".into(),
            message: "m".into(),
            help: "h".into(),
        }],
    };
    assert!(!e.is_recoverable());
    assert!(!e.is_eval());
    // Clone preserves the diagnostics (futures replay terminal errors).
    match e.clone() {
        FutureError::Rejected { diagnostics } => assert_eq!(diagnostics.len(), 1),
        other => panic!("clone changed the error kind: {other:?}"),
    }
}

#[test]
fn default_config_stays_out_of_the_way() {
    // The 500MiB default budget and Allow-heavy defaults must not reject
    // or warn on an ordinary seeded future.
    let s = Session::with_plan(PlanSpec::sequential());
    let mut env = Env::new();
    env.insert("x", 21.0f64);
    let v = s
        .scope(|_| {
            let f = future_with(
                Expr::mul(Expr::var("x"), Expr::lit(2.0)),
                &env,
                FutureOpts::new(),
            )
            .unwrap();
            f.value().unwrap()
        });
    assert_eq!(v, Value::F64(42.0));
    let counters = rustures::metrics::session_analysis_counters(s.id());
    assert_eq!((counters.denies, counters.warns), (0, 0));
    s.close();
}
