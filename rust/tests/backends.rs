//! Cross-backend integration: the paper's core behavioural examples,
//! exercised on every backend (E2, E10 in DESIGN.md).

use std::time::{Duration, Instant};

use rustures::api::future::values;
use rustures::api::plan::{with_plan, PlanSpec};
use rustures::prelude::*;

fn all_specs() -> Vec<PlanSpec> {
    vec![
        PlanSpec::sequential(),
        PlanSpec::multicore(2),
        PlanSpec::multiprocess(2),
        PlanSpec::cluster(&["n1.local", "n2.local"]),
        PlanSpec::batch(2),
    ]
}

#[test]
fn same_program_same_result_on_every_backend() {
    // The framework's headline promise: identical results everywhere.
    let mut outcomes = Vec::new();
    for spec in all_specs() {
        let name = spec.name();
        let out = with_plan(spec, || {
            let mut env = Env::new();
            env.insert("base", 7i64);
            let xs: Vec<Value> = (0..10i64).map(Value::I64).collect();
            future_lapply(
                &xs,
                "x",
                &Expr::add(Expr::mul(Expr::var("x"), Expr::var("x")), Expr::var("base")),
                &env,
                &LapplyOpts::new(),
            )
            .unwrap()
        });
        outcomes.push((name, out));
    }
    let reference = outcomes[0].1.clone();
    for (name, out) in &outcomes {
        assert_eq!(*out, reference, "backend {name} diverged");
    }
}

#[test]
fn blocking_three_futures_two_workers() {
    // Paper: "when we attempt to create a third future ... future() blocks
    // until one of the workers is available".
    for spec in [PlanSpec::multicore(2), PlanSpec::multiprocess(2)] {
        let name = spec.name();
        with_plan(spec, || {
            let env = Env::new();
            let t0 = Instant::now();
            let _f1 = future(Expr::Spin { millis: 200 }, &env).unwrap();
            let _f2 = future(Expr::Spin { millis: 200 }, &env).unwrap();
            let create_two = t0.elapsed();
            assert!(
                create_two < Duration::from_millis(150),
                "{name}: first two creates must not block, took {create_two:?}"
            );
            let t1 = Instant::now();
            let f3 = future(Expr::lit(3i64), &env).unwrap();
            let create_third = t1.elapsed();
            assert!(
                create_third >= Duration::from_millis(50),
                "{name}: third create should block, took {create_third:?}"
            );
            assert_eq!(f3.value().unwrap(), Value::I64(3));
        });
    }
}

#[test]
fn worker_frees_on_resolution_not_collection() {
    // Regression for the launch deadlock: create 4 on 2 workers and only
    // collect at the end — must complete.
    for spec in [PlanSpec::multicore(2), PlanSpec::multiprocess(2), PlanSpec::batch(2)] {
        let name = spec.name();
        with_plan(spec, || {
            let env = Env::new();
            let fs: Vec<Future> = (0..4)
                .map(|i| {
                    future(
                        Expr::seq(vec![Expr::Spin { millis: 20 }, Expr::lit(i as i64)]),
                        &env,
                    )
                    .unwrap()
                })
                .collect();
            let vs = values(&fs).unwrap();
            assert_eq!(vs, (0..4).map(Value::I64).collect::<Vec<_>>(), "{name}");
        });
    }
}

#[test]
fn eval_errors_relay_identically_everywhere() {
    for spec in all_specs() {
        let name = spec.name();
        with_plan(spec, || {
            let env = Env::new();
            let f = future(Expr::stop(Expr::lit("deliberate failure")), &env).unwrap();
            match f.value() {
                Err(FutureError::Eval(e)) => {
                    assert_eq!(e.message, "deliberate failure", "{name}")
                }
                other => panic!("{name}: expected eval error, got {other:?}"),
            }
        });
    }
}

#[test]
fn rng_identical_across_backends_and_worker_counts() {
    // E5: "fully reproducible regardless of future backend specified and
    // the number of workers available".
    let draw = |spec: PlanSpec| {
        with_plan(spec, || {
            let env = Env::new();
            let xs: Vec<Value> = (0..6i64).map(Value::I64).collect();
            future_lapply(&xs, "x", &Expr::rnorm(2), &env, &LapplyOpts::new().seed(2024))
                .unwrap()
        })
    };
    let reference = draw(PlanSpec::sequential());
    for spec in [
        PlanSpec::multicore(1),
        PlanSpec::multicore(3),
        PlanSpec::multiprocess(2),
        PlanSpec::cluster(&["n1.local", "n2.local", "n3.local"]),
        PlanSpec::batch(2),
    ] {
        let name = spec.name();
        let w = spec.effective_workers();
        assert_eq!(draw(spec), reference, "backend {name} ({w} workers) diverged");
    }
}

#[test]
fn seeded_lapply_bit_identical_across_chunkings_and_backends() {
    // The MapChunk RNG contract end to end: a seeded future_lapply must be
    // BIT-identical for every chunking policy on every backend — including
    // the serializing multiprocess path, which exercises the chunk wire
    // encoding (body once + packed elements).
    let xs: Vec<Value> = (0..9i64).map(Value::I64).collect();
    let body = Expr::add(Expr::var("x"), Expr::runif(2));
    let policies = [
        ("per-element", Chunking::PerElement),
        ("chunk=4", Chunking::ChunkSize(4)),
        ("per-worker", Chunking::PerWorker),
    ];
    let mut outcomes: Vec<(String, Vec<Value>)> = Vec::new();
    for spec in [PlanSpec::sequential(), PlanSpec::multicore(2), PlanSpec::multiprocess(2)] {
        for (label, chunking) in policies {
            let out = with_plan(spec.clone(), || {
                future_lapply(
                    &xs,
                    "x",
                    &body,
                    &Env::new(),
                    &LapplyOpts::new().seed(1234).chunking(chunking),
                )
                .unwrap()
            });
            outcomes.push((format!("{}/{}", spec.name(), label), out));
        }
    }
    let (ref_name, reference) = outcomes[0].clone();
    assert_eq!(reference.len(), xs.len());
    for (name, out) in &outcomes {
        assert_eq!(out, &reference, "{name} diverged from {ref_name}");
    }
}

#[test]
fn future_either_picks_fast_racer() {
    for spec in [PlanSpec::multicore(3), PlanSpec::multiprocess(3)] {
        let name = spec.name();
        with_plan(spec, || {
            let env = Env::new();
            let v = future_either(
                vec![
                    Expr::seq(vec![Expr::Spin { millis: 400 }, Expr::lit("slow")]),
                    Expr::seq(vec![Expr::Spin { millis: 5 }, Expr::lit("fast")]),
                    Expr::seq(vec![Expr::Spin { millis: 400 }, Expr::lit("slow2")]),
                ],
                &env,
            )
            .unwrap();
            assert_eq!(v, Value::Str("fast".into()), "{name}");
        });
    }
}

#[test]
fn future_creation_is_zero_copy_in_payload_bytes() {
    // Tensor payloads are Arc-shared: capturing a 1 MiB global into a
    // future bumps a refcount instead of copying the buffer.  A third
    // allocation appearing here means the zero-copy hot path regressed.
    use std::sync::Arc;
    with_plan(PlanSpec::multicore(2), || {
        let t = Tensor::zeros(&[1 << 18]); // 1 MiB of f32s
        let base = Arc::strong_count(&t.data);
        let mut env = Env::new();
        env.insert("t", t.clone());
        let f = future_with(
            Expr::prim(PrimOp::Sum, vec![Expr::var("t")]),
            &env,
            FutureOpts::new().lazy(),
        )
        .unwrap();
        // One share in the env binding + one in the lazy task's captured
        // globals — and nothing else.
        assert_eq!(
            Arc::strong_count(&t.data),
            base + 2,
            "payload buffer was deep-copied on the creation path"
        );
        assert_eq!(f.value().unwrap(), Value::F64(0.0));
    });
}

#[test]
fn promises_and_listenv_work_on_parallel_backends() {
    with_plan(PlanSpec::multiprocess(2), || {
        let mut env = Env::new();
        env.insert("xs", Value::List((1..=3i64).map(Value::I64).collect()));
        let mut vs = ListEnv::new();
        for i in 0..3usize {
            vs.assign(
                i,
                Expr::mul(
                    Expr::index(Expr::var("xs"), Expr::lit(i as i64)),
                    Expr::lit(10i64),
                ),
                &env,
            )
            .unwrap();
        }
        assert_eq!(
            vs.as_list().unwrap(),
            vec![Value::I64(10), Value::I64(20), Value::I64(30)]
        );
    });
}

#[test]
fn stdout_and_warnings_relay_from_remote_workers() {
    use rustures::api::conditions::{set_sink, ConditionKind, RecordingSink};
    with_plan(PlanSpec::multiprocess(1), || {
        let env = Env::new();
        let f = future(
            Expr::seq(vec![
                Expr::cat(Expr::lit("remote output\n")),
                Expr::warning(Expr::lit("remote warning")),
                Expr::lit(1i64),
            ]),
            &env,
        )
        .unwrap();
        let rec = RecordingSink::new();
        set_sink(Some(Box::new(rec.clone())));
        let v = f.value();
        set_sink(None);
        assert_eq!(v.unwrap(), Value::I64(1));
        assert_eq!(rec.stdout_text(), "remote output\n");
        let conds = rec.conditions();
        assert_eq!(conds.len(), 1);
        assert_eq!(conds[0].kind, ConditionKind::Warning);
        assert_eq!(conds[0].message, "remote warning");
    });
}

#[test]
fn progress_conditions_relay_before_value_on_live_backends() {
    use rustures::api::conditions::{set_sink, ConditionKind, RecordingSink};
    with_plan(PlanSpec::multiprocess(1), || {
        let env = Env::new();
        let f = future(
            Expr::seq(vec![
                Expr::progress(Expr::lit("50%")),
                Expr::Spin { millis: 50 },
                Expr::lit(0i64),
            ]),
            &env,
        )
        .unwrap();
        let rec = RecordingSink::new();
        set_sink(Some(Box::new(rec.clone())));
        // Poll without collecting: the immediate should arrive live.
        let deadline = Instant::now() + Duration::from_secs(5);
        while rec.conditions().is_empty() && Instant::now() < deadline {
            let _ = f.resolved();
            std::thread::sleep(Duration::from_millis(5));
        }
        let got_live = !rec.conditions().is_empty();
        let _ = f.value();
        set_sink(None);
        assert!(got_live, "immediateCondition did not relay before value()");
        assert_eq!(rec.conditions()[0].kind, ConditionKind::Immediate);
    });
}

#[test]
fn foreach_adaptor_runs_on_parallel_backend() {
    use rustures::mapreduce::foreach::{foreach, Combine};
    with_plan(PlanSpec::multicore(2), || {
        let env = Env::new();
        let total = foreach("i", (1..=10i64).map(Value::I64).collect(), &env)
            .combine(Combine::Sum)
            .dopar(Expr::mul(Expr::var("i"), Expr::var("i")))
            .unwrap();
        assert_eq!(total, Value::F64(385.0)); // sum of squares 1..10
    });
}
