//! The result cache, end to end through the public API: warm hits served
//! from the disk tier survive session (and would survive process) restarts
//! with ZERO capacity footprint, torn scratch files are swept and never
//! published, corrupt disk objects quarantine as misses and self-heal,
//! cached `future_lapply` is chunking-invariant across sessions, and eval
//! errors never populate the store.

use std::fs;
use std::path::PathBuf;

use rustures::cache::{self, CacheStore};
use rustures::prelude::*;
use rustures::util::uuid_v4;

fn temp_root(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("rustures-it-cache-{tag}-{}", uuid_v4()))
}

fn xs(n: i64) -> Vec<Value> {
    (0..n).map(Value::I64).collect()
}

/// Elements of `objects/` under a store root (the content-named frames).
fn object_names(root: &PathBuf) -> Vec<String> {
    let mut names: Vec<String> = fs::read_dir(root.join("objects"))
        .map(|rd| rd.flatten().map(|e| e.file_name().to_string_lossy().into_owned()).collect())
        .unwrap_or_default();
    names.sort();
    names
}

/// A cold session publishes through the disk tier; a FRESH session (empty
/// memory tier — the in-memory tier is per-session) then hits purely from
/// disk, takes no in-flight permit and no lease, leaves no row in
/// `capacity_json`, and the hit is visible in `cache_json`.
#[test]
fn disk_tier_survives_sessions_with_zero_capacity_footprint() {
    let root = temp_root("restart");
    let expr = Expr::add(Expr::lit(40i64), Expr::lit(2i64));

    let cold = Session::with_plan(PlanSpec::Sequential);
    cold.set_cache_config(CacheConfig::new().disk(&root));
    let v = cold
        .scope(|_| future_with(expr.clone(), &Env::new(), FutureOpts::new().cached()))
        .unwrap()
        .value()
        .unwrap();
    assert_eq!(v, Value::I64(42));
    let c = cache::session_counters(cold.id());
    assert_eq!(c.disk.publishes, 1, "cold run must spill to disk: {c:?}");
    cold.close();
    assert_eq!(object_names(&root).len(), 1, "one content-named object after cold run");

    let warm = Session::with_plan(PlanSpec::Sequential);
    warm.set_cache_config(CacheConfig::new().disk(&root));
    let v = warm
        .scope(|_| future_with(expr, &Env::new(), FutureOpts::new().cached()))
        .unwrap()
        .value()
        .unwrap();
    assert_eq!(v, Value::I64(42));
    let c = cache::session_counters(warm.id());
    assert_eq!(c.disk.hits, 1, "warm session must hit via the disk tier: {c:?}");
    assert_eq!(c.disk.publishes, 0, "a hit must not re-publish");
    assert_eq!(
        rustures::capacity::session_peak_in_use(warm.id()),
        0,
        "a pure-hit session must never hold a lease"
    );
    assert!(
        !rustures::metrics::capacity_json().contains(&format!("\"session\":{}", warm.id())),
        "a pure-hit session must be absent from capacity_json"
    );
    let json = rustures::metrics::cache_json();
    assert!(json.contains("\"schema\":\"rustures.cache.v1\""), "schema tag: {json}");
    assert!(json.contains(&format!("\"session\":{}", warm.id())), "hit session row: {json}");
    warm.close();

    let _ = fs::remove_dir_all(&root);
}

/// A crashed publisher leaves only a scratch orphan; `CacheStore::open`
/// sweeps it, and a torn file can never become an object (publish goes
/// through its own scratch file + atomic rename).
#[test]
fn torn_scratch_files_are_swept_and_never_published() {
    let root = temp_root("torn");
    let _ = CacheStore::open(&root).unwrap();

    // Simulate a publisher that died mid-write: a half-frame in scratch/.
    let torn = root.join("scratch").join("4242-deadbeef");
    fs::write(&torn, b"half a frame").unwrap();

    let store = CacheStore::open(&root).unwrap();
    assert!(!torn.exists(), "reopening the store must sweep torn scratch files");
    assert!(object_names(&root).is_empty(), "a torn write must never surface as an object");

    // A real publish still lands, content-named, and is immutable.
    let key = cache::cache_key(&Expr::lit(7i64), &Env::new(), None, 0);
    assert!(store.publish(&key, b"frame-bytes").unwrap());
    assert!(!store.publish(&key, b"other-bytes").unwrap(), "first write wins");
    assert_eq!(object_names(&root), vec![key.to_string()]);
    assert_eq!(store.load(&key).unwrap(), b"frame-bytes");
    assert!(
        fs::read_dir(root.join("scratch")).unwrap().next().is_none(),
        "publish must leave no scratch residue"
    );

    let _ = fs::remove_dir_all(&root);
}

/// A bit-rotted object fails the wire decode, is deleted, reports a miss —
/// and the re-evaluation heals the store with a fresh publish.
#[test]
fn corrupt_disk_objects_quarantine_as_misses_and_self_heal() {
    let root = temp_root("corrupt");
    let expr = Expr::add(Expr::lit(20i64), Expr::lit(22i64));

    let cold = Session::with_plan(PlanSpec::Sequential);
    cold.set_cache_config(CacheConfig::new().disk(&root));
    cold.scope(|_| future_with(expr.clone(), &Env::new(), FutureOpts::new().cached()))
        .unwrap()
        .value()
        .unwrap();
    cold.close();

    // Non-RNG expression: the key excludes the stream index, so it is
    // recomputable here without knowing the session's ordinal assignment.
    let key = cache::cache_key(&expr, &Env::new(), None, 0);
    let store = CacheStore::open(&root).unwrap();
    let object = store.object_path(&key);
    assert!(object.exists(), "cold run must have published under the public key derivation");
    fs::write(&object, b"bit rot").unwrap();

    let warm = Session::with_plan(PlanSpec::Sequential);
    warm.set_cache_config(CacheConfig::new().disk(&root));
    let v = warm
        .scope(|_| future_with(expr, &Env::new(), FutureOpts::new().cached()))
        .unwrap()
        .value()
        .unwrap();
    assert_eq!(v, Value::I64(42), "a corrupt entry must fall back to evaluation");
    let c = cache::session_counters(warm.id());
    assert_eq!(c.disk.hits, 0, "a corrupt object must not count as a hit: {c:?}");
    assert!(c.disk.misses >= 1, "quarantine reports a miss: {c:?}");
    assert_eq!(c.disk.publishes, 1, "re-evaluation re-publishes: {c:?}");
    warm.close();

    let bytes = fs::read(&object).unwrap();
    assert_ne!(bytes, b"bit rot".to_vec(), "the store must self-heal the object");

    let _ = fs::remove_dir_all(&root);
}

/// Per-element keying makes cached maps chunking-invariant: a warm session
/// under a DIFFERENT chunking hits every element published by the cold one,
/// and the values are bit-identical to both the cold run and a cache-free
/// reference.
#[test]
fn cached_lapply_is_chunking_invariant_across_sessions() {
    let root = temp_root("chunks");
    let body = Expr::add(Expr::var("x"), Expr::runif(1));
    let elements = xs(12);
    let env = Env::new();
    let opts = |chunk| LapplyOpts::new().seed(11).chunking(chunk).cached();

    let run = |chunk| {
        let s = Session::with_plan(PlanSpec::Sequential);
        s.set_cache_config(CacheConfig::new().disk(&root));
        let got = s.lapply(&elements, "x", &body, &env, &opts(chunk)).unwrap();
        let counters = cache::session_counters(s.id());
        s.close();
        (got, counters)
    };

    let (cold, cold_c) = run(Chunking::ChunkSize(4));
    let (warm, warm_c) = run(Chunking::ChunkSize(5));
    assert_eq!(warm, cold, "warm run under different chunking must be bit-identical");
    assert_eq!(cold_c.disk.publishes, 12, "one object per element: {cold_c:?}");
    assert_eq!(warm_c.disk.hits, 12, "every element hits under the new chunking: {warm_c:?}");
    assert_eq!(warm_c.disk.publishes, 0, "nothing re-published on a warm run: {warm_c:?}");

    // Reference: same seed, cache disabled — the cache is invisible.
    let s = Session::with_plan(PlanSpec::Sequential);
    s.set_cache_config(CacheConfig::disabled());
    let reference =
        s.lapply(&elements, "x", &body, &env, &opts(Chunking::ChunkSize(3))).unwrap();
    assert_eq!(cache::session_counters(s.id()), cache::CacheCounters::default());
    s.close();
    assert_eq!(reference, cold, "disabled-cache reference must match");

    let _ = fs::remove_dir_all(&root);
}

/// Eval errors are never cached: the store stays empty and a second cached
/// creation misses and errors again.
#[test]
fn eval_errors_never_reach_the_store() {
    let root = temp_root("errors");
    for round in 0..2 {
        let s = Session::with_plan(PlanSpec::Sequential);
        s.set_cache_config(CacheConfig::new().disk(&root));
        let f = s
            .scope(|_| {
                future_with(
                    Expr::stop(Expr::lit("nope")),
                    &Env::new(),
                    FutureOpts::new().cached(),
                )
            })
            .unwrap();
        match f.value() {
            Err(FutureError::Eval(e)) => assert_eq!(e.message, "nope"),
            other => panic!("round {round}: expected eval error, got {other:?}"),
        }
        let c = cache::session_counters(s.id());
        assert_eq!(c.memory.publishes + c.disk.publishes, 0, "round {round}: {c:?}");
        assert!(c.memory.misses >= 1, "round {round} must consult and miss: {c:?}");
        s.close();
    }
    assert!(object_names(&root).is_empty(), "error results must never land on disk");
    let _ = fs::remove_dir_all(&root);
}
