//! Capacity-governed execution, end to end: per-session `max_workers`
//! quotas bound real concurrency without changing results, supervisor
//! respawns cannot overshoot a quota, queued dispatch respects quotas, the
//! batch scheduler daemon's own death surfaces structured errors (never a
//! hang), and `metrics::capacity_json()` renders the ledger.

use std::time::Duration;

use rustures::api::expr::PrimOp;
use rustures::api::session::Session;
use rustures::capacity::{self, SessionLimits};
use rustures::prelude::*;
use rustures::util::exe::worker_exe;

fn xs(n: i64) -> Vec<Value> {
    (0..n).map(Value::I64).collect()
}

/// One seeded draw per element, so bit-identity against a reference run is
/// meaningful.
fn seeded_body() -> Expr {
    Expr::add(Expr::var("x"), Expr::runif(1))
}

/// Map body: element `kill_at` kills its worker once (marker-gated), then
/// every element draws — the conformance suite's chaos shape.
fn kill_once_body(kill_at: i64, marker: &str) -> Expr {
    Expr::seq(vec![
        Expr::if_else(
            Expr::prim(PrimOp::Eq, vec![Expr::var("x"), Expr::lit(kill_at)]),
            Expr::chaos_kill_once(marker),
            Expr::lit(0i64),
        ),
        Expr::add(Expr::var("x"), Expr::runif(1)),
    ])
}

fn chaos_marker(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("rustures-capacity-{tag}-{}", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// The acceptance shape: a session with `max_workers = 2` running a
/// 64-element lapply never exceeds 2 concurrent leases and completes
/// bit-identically to an unlimited run — on a pool with MORE than 2
/// workers, so the quota (not the pool size) is what bounds concurrency.
#[test]
fn quota_capped_lapply_is_bit_identical_and_bounded() {
    let elements = xs(64);
    let body = seeded_body();
    let env = Env::new();
    let opts = || LapplyOpts::new().seed(41).chunking(Chunking::ChunkSize(8));

    for spec in [PlanSpec::multicore(4), PlanSpec::multiprocess(4)] {
        if matches!(spec, PlanSpec::Multiprocess { .. }) && worker_exe().is_err() {
            continue; // worker binary not built (unit-test-only invocation)
        }
        let unlimited = Session::with_plan(spec.clone());
        let want = unlimited.lapply(&elements, "x", &body, &env, &opts()).unwrap();
        unlimited.close();

        let s = Session::with_limits(spec.clone(), SessionLimits::new().max_workers(2));
        let got = s.lapply(&elements, "x", &body, &env, &opts()).unwrap();
        let peak = capacity::session_peak_in_use(s.id());
        s.close();
        assert_eq!(got, want, "{}: quota must not change results", spec.name());
        assert!(
            peak <= 2,
            "{}: max_workers = 2 but peak concurrent leases was {peak}",
            spec.name()
        );
    }
}

/// Regression (ledger migration): a supervisor respawn restores capacity
/// but must NOT let a quota-capped session exceed `max_workers` — kills
/// mid-map, with retry, still complete bit-identically and the session's
/// lease high-water mark stays at the cap.
#[test]
fn respawn_cannot_exceed_session_quota() {
    let elements = xs(16);
    let env = Env::new();
    let retry = RetryPolicy::idempotent(4).with_backoff(Duration::from_millis(1), 2.0);
    let opts = |retry: Option<RetryPolicy>| {
        let o = LapplyOpts::new().seed(59).chunking(Chunking::ChunkSize(2));
        match retry {
            Some(r) => o.retry(r),
            None => o,
        }
    };

    // Clean reference, unlimited.
    let clean_body =
        Expr::seq(vec![Expr::lit(0i64), Expr::add(Expr::var("x"), Expr::runif(1))]);
    let reference = Session::with_plan(PlanSpec::multicore(4));
    let want = reference.lapply(&elements, "x", &clean_body, &env, &opts(None)).unwrap();
    reference.close();

    // Quota-capped run that loses a worker mid-map.
    let marker = chaos_marker("respawn-quota");
    let body = kill_once_body(5, &marker);
    let s = Session::with_limits(PlanSpec::multicore(4), SessionLimits::new().max_workers(2));
    let got = s.lapply(&elements, "x", &body, &env, &opts(Some(retry))).unwrap();
    let peak = capacity::session_peak_in_use(s.id());
    let counters = s.supervision_counters();
    s.close();
    let _ = std::fs::remove_file(&marker);

    assert_eq!(got, want, "kill + retry under a quota must stay bit-identical");
    assert!(counters.worker_deaths >= 1, "the chaos kill must have been observed");
    assert!(
        peak <= 2,
        "respawn overshot the session quota: peak concurrent leases {peak} > 2"
    );
}

/// `Queued`-backlogged admission: queued dispatch enqueues without
/// blocking creation, but seat acquisition still flows through the ledger
/// — the quota bounds concurrency exactly like the blocking path.
#[test]
fn queued_dispatch_respects_quota() {
    let elements = xs(32);
    let body = seeded_body();
    let env = Env::new();
    let opts = || LapplyOpts::new().seed(67).chunking(Chunking::ChunkSize(4)).queued();

    let unlimited = Session::with_plan(PlanSpec::multicore(4));
    let want = unlimited.lapply(&elements, "x", &body, &env, &opts()).unwrap();
    unlimited.close();

    let s = Session::with_limits(PlanSpec::multicore(4), SessionLimits::new().max_workers(2));
    let got = s.lapply(&elements, "x", &body, &env, &opts()).unwrap();
    let peak = capacity::session_peak_in_use(s.id());
    s.close();
    assert_eq!(got, want);
    assert!(peak <= 2, "queued dispatch overshot the quota: peak {peak} > 2");
}

/// Chaos for the batch scheduler daemon ITSELF (not just job processes):
/// with futures queued and running, the daemon dies — every future must
/// surface a structured `FutureError` (or its already-computed value),
/// never hang, and new submissions must fail fast.
#[test]
fn batch_daemon_death_surfaces_structured_errors_not_hangs() {
    if worker_exe().is_err() {
        return; // worker binary not built (unit-test-only invocation)
    }
    let s = Session::with_plan(PlanSpec::batch(2));
    let env = Env::new();
    // More futures than slots: some run, some sit in the daemon's queue.
    let futures: Vec<Future> = (0..6)
        .map(|i| {
            s.future_with(
                Expr::seq(vec![Expr::Sleep { millis: 40 }, Expr::lit(i as i64)]),
                &env,
                FutureOpts::new().queued(),
            )
            .unwrap()
        })
        .collect();

    rustures::scheduler::arm_chaos_daemondie();

    // Collect on a helper thread so a hang fails the test in bounded time
    // instead of wedging the whole run.
    let (tx, rx) = std::sync::mpsc::channel();
    std::thread::spawn(move || {
        let outcomes: Vec<Result<Value, FutureError>> =
            futures.iter().map(|f| f.value()).collect();
        let _ = tx.send(outcomes);
    });
    let outcomes = rx
        .recv_timeout(Duration::from_secs(30))
        .expect("queued futures hung after the scheduler daemon died");
    for (i, outcome) in outcomes.iter().enumerate() {
        match outcome {
            // Finished before the daemon died: the value survives.
            Ok(v) => assert_eq!(*v, Value::I64(i as i64)),
            // Killed with the daemon: structured infrastructure error,
            // never a relayed eval error and never a hang.
            Err(e) => assert!(!e.is_eval(), "future {i}: expected infrastructure error, got {e}"),
        }
    }

    // The dead daemon rejects new work immediately.
    match s.future(Expr::lit(1i64), &env) {
        Err(FutureError::Launch(msg)) => assert!(msg.contains("daemon"), "{msg}"),
        Ok(f) => match f.value() {
            Err(e) => assert!(!e.is_eval(), "expected structured failure, got {e}"),
            Ok(v) => panic!("dead scheduler daemon completed a future: {v:?}"),
        },
        Err(other) => assert!(!other.is_eval(), "unexpected error kind: {other}"),
    }
    s.close();
}

/// The metrics surface: `rustures.capacity.v1` renders per-pool/per-host
/// seat states and per-session usage/limits.
#[test]
fn capacity_json_renders_pools_and_session_usage() {
    let s = Session::with_limits(PlanSpec::multicore(2), SessionLimits::new().max_workers(2));
    let env = Env::new();
    let f = s.future(Expr::Spin { millis: 60 }, &env).unwrap();
    let doc = rustures::util::json::parse(&rustures::metrics::capacity_json())
        .expect("capacity_json must be valid JSON");
    assert_eq!(
        doc.get("schema").and_then(|v| v.as_str()),
        Some("rustures.capacity.v1")
    );
    let pools = doc.get("pools").unwrap().as_arr().unwrap();
    let mine = pools
        .iter()
        .find(|p| {
            p.get("backend").and_then(|b| b.as_str()) == Some("multicore")
                && p.get("session").and_then(|v| v.as_i64()) == Some(s.id() as i64)
        })
        .expect("the session's multicore pool must appear");
    let hosts = mine.get("hosts").unwrap().as_arr().unwrap();
    assert_eq!(hosts[0].get("host").unwrap().as_str(), Some("local"));
    assert_eq!(hosts[0].get("total").unwrap().as_i64(), Some(2));
    let sessions = doc.get("sessions").unwrap().as_arr().unwrap();
    let entry = sessions
        .iter()
        .find(|e| e.get("session").and_then(|v| v.as_i64()) == Some(s.id() as i64))
        .expect("the limited session must appear");
    assert_eq!(entry.get("max_workers").unwrap().as_i64(), Some(2));
    f.value().unwrap();
    s.close();
}

/// `max_in_flight`: future creation blocks at the cap and resumes as
/// earlier futures resolve — backpressure, never a drop.
#[test]
fn max_in_flight_gates_future_creation() {
    let s = Session::with_limits(
        PlanSpec::multicore(2),
        SessionLimits::new().max_in_flight(2),
    );
    let env = Env::new();
    let f1 = s.future(Expr::lit(1i64), &env).unwrap();
    let f2 = s.future(Expr::lit(2i64), &env).unwrap();
    // Two futures in flight: a third creation must block until one is
    // collected (terminal observation frees the permit).
    let s2 = s.clone();
    let (tx, rx) = std::sync::mpsc::channel();
    let creator = std::thread::spawn(move || {
        let f3 = s2.future(Expr::lit(3i64), &env).unwrap();
        let _ = tx.send(());
        f3.value().unwrap()
    });
    assert!(
        rx.recv_timeout(Duration::from_millis(80)).is_err(),
        "third creation must block at max_in_flight = 2"
    );
    assert_eq!(f1.value().unwrap(), Value::I64(1)); // terminal: permit frees
    rx.recv_timeout(Duration::from_secs(5))
        .expect("freed in-flight permit must admit the blocked creation");
    assert_eq!(creator.join().unwrap(), Value::I64(3));
    assert_eq!(f2.value().unwrap(), Value::I64(2));
    s.close();
}
