//! Kill-during-serialization chaos: a worker process dies **halfway
//! through writing a result frame** (truncated length-prefixed frame on
//! the pipe/socket).  The coordinator's reader must surface a structured
//! `Channel` error — distinguishable from a clean crash-at-boundary
//! (`WorkerDied`) — and a supervised retry must re-run the lost chunk
//! under the same RNG substreams, bit-identically to a no-failure run.
//!
//! The probe is armed via `supervisor::set_chaos_midwrite_marker`: process
//! spawners pass the marker path to children in `RUSTURES_CHAOS_MIDWRITE`,
//! and the child kills itself mid-write exactly once (marker file).  The
//! knob is process-global, so tests in this binary serialize on a mutex.

use std::sync::Mutex;
use std::time::Duration;

use rustures::backend::supervisor::set_chaos_midwrite_marker;
use rustures::mapreduce::Chunking;
use rustures::prelude::*;
use rustures::proptest_lite::Gen;

static CHAOS_LOCK: Mutex<()> = Mutex::new(());

fn marker_path(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("rustures-midwrite-{tag}-{}", std::process::id()))
        .to_string_lossy()
        .into_owned()
}

/// Disarm + clean up even on panic.
struct Disarm(String);

impl Drop for Disarm {
    fn drop(&mut self) {
        set_chaos_midwrite_marker(None);
        let _ = std::fs::remove_file(&self.0);
    }
}

fn xs(n: i64) -> Vec<Value> {
    (0..n).map(Value::I64).collect()
}

#[test]
fn kill_mid_result_write_surfaces_structured_channel_error() {
    let _guard = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let marker = marker_path("structured");
    let _disarm = Disarm(marker.clone());
    set_chaos_midwrite_marker(Some(&marker));

    let s = Session::with_plan(PlanSpec::multiprocess(2));
    let env = Env::new();
    let body = Expr::add(Expr::var("x"), Expr::runif(1));
    // No retry: the torn frame must surface as a structured, recoverable,
    // NON-eval failure — specifically the reader's Channel error (mid-frame
    // truncation), not a masqueraded evaluation error, and never a hang.
    let got = s.lapply(
        &xs(6),
        "x",
        &body,
        &env,
        &LapplyOpts::new().seed(3).chunking(Chunking::ChunkSize(2)),
    );
    match got {
        Err(e) => {
            assert!(!e.is_eval(), "torn write must not masquerade as eval error: {e}");
            assert!(e.is_recoverable(), "torn write must be recoverable: {e}");
            assert!(
                matches!(e, FutureError::Channel(_)),
                "mid-frame truncation should surface as Channel, got {e:?}"
            );
        }
        Ok(v) => panic!("expected the torn-frame failure, got values {v:?}"),
    }

    // Capacity recovered (respawn): the session still serves.
    let f = s.future(Expr::lit(5i64), &env).unwrap();
    assert_eq!(f.value().unwrap(), Value::I64(5));
    s.close();
}

#[test]
fn retry_after_mid_write_kill_is_bit_identical_property() {
    // Property (proptest_lite cases over seed × chunking × size): a seeded
    // map that loses a result to a mid-write kill, under an idempotent
    // retry policy, returns BIT-IDENTICAL values to the clean run.
    let _guard = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    for case in 0..3u64 {
        let mut g = Gen::new(0xC0FFEE ^ case, case);
        let seed = g.u64();
        let n = g.usize_in(4, 8) as i64;
        let chunk = g.usize_in(1, 3);

        let env = Env::new();
        let body = Expr::add(Expr::var("x"), Expr::runif(2));
        let opts = LapplyOpts::new()
            .seed(seed)
            .chunking(Chunking::ChunkSize(chunk))
            .retry(RetryPolicy::idempotent(5).with_backoff(Duration::from_millis(1), 2.0));

        // Clean reference run (chaos disarmed).
        set_chaos_midwrite_marker(None);
        let clean_session = Session::with_plan(PlanSpec::multiprocess(2));
        let want = clean_session.lapply(&xs(n), "x", &body, &env, &opts).unwrap();
        clean_session.close();

        // Chaos run: first completed result frame is torn; retry re-runs
        // the lost chunk under the same base_index substreams.
        let marker = marker_path(&format!("prop-{case}"));
        let _disarm = Disarm(marker.clone());
        set_chaos_midwrite_marker(Some(&marker));
        let s = Session::with_plan(PlanSpec::multiprocess(2));
        let got = s.lapply(&xs(n), "x", &body, &env, &opts).unwrap();
        s.close();

        assert_eq!(
            got, want,
            "case {case}: seed={seed} n={n} chunk={chunk} — retried run must be bit-identical"
        );
        assert!(
            std::path::Path::new(&marker).exists(),
            "case {case}: the chaos probe never fired"
        );
    }
}

#[test]
fn cluster_reader_also_surfaces_torn_frames() {
    // Same failure mode over TCP (cluster backend): the socket reader sees
    // the truncated frame and the supervised retry recovers bit-identically.
    let _guard = CHAOS_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let env = Env::new();
    let body = Expr::add(Expr::var("x"), Expr::runif(1));
    let opts = LapplyOpts::new()
        .seed(11)
        .chunking(Chunking::ChunkSize(2))
        .retry(RetryPolicy::idempotent(4).with_backoff(Duration::from_millis(1), 2.0));

    set_chaos_midwrite_marker(None);
    let clean = Session::with_plan(PlanSpec::cluster(&["c1", "c2"]));
    let want = clean.lapply(&xs(6), "x", &body, &env, &opts).unwrap();
    clean.close();

    let marker = marker_path("cluster");
    let _disarm = Disarm(marker.clone());
    set_chaos_midwrite_marker(Some(&marker));
    let s = Session::with_plan(PlanSpec::cluster(&["c1", "c2"]));
    let got = s.lapply(&xs(6), "x", &body, &env, &opts).unwrap();
    s.close();
    assert_eq!(got, want, "cluster retried run must be bit-identical");
}
