//! E9: the Future API conformance suite (future.tests analog) passes on
//! every built-in backend — the paper's validation contract.

use rustures::api::plan::PlanSpec;
use rustures::conformance::run_conformance;

fn assert_conforms(spec: PlanSpec) {
    let report = run_conformance(spec);
    let failures: Vec<String> = report
        .results
        .iter()
        .filter(|r| !r.passed)
        .map(|r| format!("{}: {}", r.name, r.detail))
        .collect();
    assert!(failures.is_empty(), "{} failed:\n{}", report.plan.name(), failures.join("\n"));
}

#[test]
fn sequential_conforms() {
    assert_conforms(PlanSpec::sequential());
}

#[test]
fn multicore_conforms() {
    assert_conforms(PlanSpec::multicore(2));
}

#[test]
fn multisession_conforms() {
    assert_conforms(PlanSpec::multiprocess(2));
}

#[test]
fn cluster_conforms() {
    assert_conforms(PlanSpec::cluster(&["n1.local", "n2.local"]));
}

#[test]
fn batchtools_conforms() {
    assert_conforms(PlanSpec::batch(2));
}

#[test]
fn third_party_backend_conforms_via_registry() {
    // The paper: "third-party contributions meeting the specifications ...
    // are automatically supported."  Register a custom backend (a thin
    // wrapper over the thread pool, as a stand-in for e.g. doRedis) and run
    // the same suite.
    use rustures::api::plan::register_backend;
    use std::sync::Arc;
    register_backend(
        "thirdparty",
        Arc::new(|workers| {
            Arc::new(rustures::backend::threadpool::ThreadPoolBackend::new(workers))
        }),
    );
    assert_conforms(PlanSpec::Custom { name: "thirdparty".into(), workers: 2 });
}
