//! Failure injection: FutureError semantics under worker death, cancelled
//! jobs, and recovery by relaunching (the paper's motivation for the
//! distinct FutureError class and its restart() future-work item).

use rustures::api::plan::{with_plan, PlanSpec};
use rustures::prelude::*;

#[test]
fn cancelled_future_surfaces_as_recoverable_error() {
    with_plan(PlanSpec::multiprocess(1), || {
        let env = Env::new();
        let f = future(Expr::Spin { millis: 5000 }, &env).unwrap();
        assert!(f.cancel(), "cancel should succeed on a running future");
        match f.value() {
            Err(e) => {
                assert!(!e.is_eval(), "cancellation is not an eval error");
                assert!(e.is_recoverable(), "cancellation should be recoverable: {e}");
            }
            Ok(_) => panic!("cancelled future returned a value"),
        }
    });
}

#[test]
fn pool_recovers_capacity_after_cancel() {
    with_plan(PlanSpec::multiprocess(1), || {
        let env = Env::new();
        let f = future(Expr::Spin { millis: 5000 }, &env).unwrap();
        assert!(f.cancel());
        let _ = f.value();
        // The single worker was killed; a new future must still run
        // (capacity respawns on demand).
        let g = future(Expr::lit(7i64), &env).unwrap();
        assert_eq!(g.value().unwrap(), Value::I64(7));
    });
}

#[test]
fn retry_pattern_relaunches_after_failure() {
    // The paper's retry({...}, times = 3, on = "FutureError") sketch.
    with_plan(PlanSpec::multiprocess(1), || {
        let env = Env::new();
        let mut attempts = 0;
        let v = loop {
            attempts += 1;
            let f = future(Expr::lit(42i64), &env).unwrap();
            if attempts == 1 {
                // Inject a failure on the first attempt.
                f.cancel();
            }
            match f.value() {
                Ok(v) => break v,
                Err(e) if e.is_recoverable() && attempts < 3 => continue,
                Err(e) => panic!("unrecoverable: {e}"),
            }
        };
        assert_eq!(v, Value::I64(42));
        assert_eq!(attempts, 2, "should have recovered on the second attempt");
    });
}

#[test]
fn batch_job_cancelled_before_start() {
    with_plan(PlanSpec::Batch { workers: 1, submit_latency_ms: 200, poll_interval_ms: 2 }, || {
        let env = Env::new();
        let f = future(Expr::lit(1i64), &env).unwrap();
        // Cancel while still pending (200ms submit latency guarantees it).
        assert!(f.cancel());
        match f.value() {
            Err(e) => assert!(e.is_recoverable(), "{e}"),
            Ok(_) => panic!("cancelled batch job returned a value"),
        }
    });
}

#[test]
fn eval_error_is_not_recoverable_but_future_error_is() {
    with_plan(PlanSpec::multicore(1), || {
        let env = Env::new();
        let f = future(Expr::stop(Expr::lit("user bug")), &env).unwrap();
        let e = f.value().unwrap_err();
        assert!(e.is_eval());
        assert!(!e.is_recoverable());
    });
}

#[test]
fn missing_global_is_neither_eval_nor_recoverable() {
    with_plan(PlanSpec::sequential(), || {
        let env = Env::new();
        let e = future(Expr::var("ghost"), &env).unwrap_err();
        assert!(!e.is_eval());
        assert!(!e.is_recoverable(), "missing global retries cannot succeed");
    });
}

#[test]
fn restart_relaunches_a_cancelled_future() {
    // The paper's restart(f) future-work item, implemented.
    with_plan(PlanSpec::multiprocess(1), || {
        let mut env = Env::new();
        env.insert("x", 21i64);
        let f = future_with(
            Expr::mul(Expr::var("x"), Expr::lit(2i64)),
            &env,
            FutureOpts::new().restartable(),
        )
        .unwrap();
        f.cancel();
        let first = f.value();
        assert!(first.is_err(), "cancelled run should fail");
        f.restart().unwrap();
        assert_eq!(f.value().unwrap(), Value::I64(42));
    });
}

#[test]
fn restart_requires_opt_in() {
    with_plan(PlanSpec::sequential(), || {
        let env = Env::new();
        let f = future(Expr::lit(1i64), &env).unwrap();
        assert!(f.restart().is_err());
    });
}
