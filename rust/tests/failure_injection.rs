//! Failure injection: FutureError semantics under worker death, cancelled
//! jobs, and recovery by relaunching (the paper's motivation for the
//! distinct FutureError class and its restart() future-work item) — plus
//! the mid-map kill harness for the supervision subsystem: workers are
//! chaos-killed in the middle of a `future_lapply` and the supervised
//! retry must reproduce the no-failure run bit-identically.

use std::sync::Mutex;
use std::time::Duration;

use rustures::api::plan::{with_plan, PlanSpec};
use rustures::liveness::{reset_liveness_config, set_liveness_config};
use rustures::prelude::*;

// ---------------------------------------------------- mid-map kill harness --

/// Unique marker path for a fail-exactly-once chaos probe.
fn marker(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("rustures-fi-{tag}-{}", rustures::util::uuid_v4()))
        .to_string_lossy()
        .into_owned()
}

/// Seeded map over `n` elements where each element in `kills` murders its
/// worker exactly once (marker-gated).  Every element draws one seeded
/// uniform, so bit-identity against a clean run is a real check.
fn killed_lapply(
    spec: PlanSpec,
    n: i64,
    kills: &[i64],
    retry: Option<RetryPolicy>,
) -> (Result<Vec<Value>, FutureError>, Vec<String>) {
    let markers: Vec<String> = kills.iter().map(|k| marker(&format!("k{k}"))).collect();
    let out = with_plan(spec, || {
        let env = Env::new();
        let xs: Vec<Value> = (0..n).map(Value::I64).collect();
        // Chain: if x == k_i (and marker_i absent) die; else fall through.
        let mut probe = Expr::lit(0i64);
        for (k, m) in kills.iter().zip(&markers) {
            probe = Expr::if_else(
                Expr::prim(PrimOp::Eq, vec![Expr::var("x"), Expr::lit(*k)]),
                Expr::chaos_kill_once(m),
                probe,
            );
        }
        let body = Expr::seq(vec![probe, Expr::add(Expr::var("x"), Expr::runif(1))]);
        let mut opts = LapplyOpts::new().seed(99).chunking(Chunking::ChunkSize(3));
        if let Some(p) = retry {
            opts = opts.retry(p);
        }
        future_lapply(&xs, "x", &body, &env, &opts)
    });
    (out, markers)
}

fn cleanup(markers: &[String]) {
    for m in markers {
        let _ = std::fs::remove_file(m);
    }
}

fn retry_policy() -> RetryPolicy {
    RetryPolicy::idempotent(4).with_backoff(Duration::from_millis(1), 2.0)
}

/// The acceptance-criteria chaos matrix: on procpool (multisession),
/// cluster, and threadpool (multicore) backends, a worker killed
/// mid-`future_lapply` yields values bit-identical to the no-failure run
/// when retry is enabled, and a structured recoverable error when not.
fn assert_midmap_kill_contract(spec: PlanSpec) {
    // Clean reference run (no kills, same seed).
    let (want, _) = killed_lapply(spec.clone(), 12, &[], None);
    let want = want.expect("clean run");

    // One kill, retry on: bit-identical recovery.
    let (got, markers) = killed_lapply(spec.clone(), 12, &[4], Some(retry_policy()));
    cleanup(&markers);
    assert_eq!(got.expect("supervised run"), want, "{}: kill+retry != clean", spec.name());

    // Two kills (two workers lost), retry on: still bit-identical.
    let (got, markers) = killed_lapply(spec.clone(), 12, &[2, 8], Some(retry_policy()));
    cleanup(&markers);
    assert_eq!(got.expect("two-kill run"), want, "{}: 2 kills + retry != clean", spec.name());

    // Kill with retry DISABLED: a structured, recoverable infrastructure
    // error — not a hang, not an eval error, not silent recovery.
    let (got, markers) = killed_lapply(spec.clone(), 12, &[4], None);
    cleanup(&markers);
    match got {
        Err(e) => {
            assert!(!e.is_eval(), "{}: worker loss reported as eval error: {e}", spec.name());
            assert!(e.is_recoverable(), "{}: worker loss not recoverable: {e}", spec.name());
        }
        Ok(_) => panic!("{}: kill without retry must fail the map", spec.name()),
    }
}

// ---------------------------------------------------- mid-map hang harness --

/// Tests that arm the process-wide stall detector serialize through this
/// guard; the config resets when the guard drops (panic-safe).
static STALL_GUARD: Mutex<()> = Mutex::new(());

struct ArmedStall(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);

impl Drop for ArmedStall {
    fn drop(&mut self) {
        reset_liveness_config();
    }
}

fn arm_stall(stall_after: Duration) -> ArmedStall {
    let g = STALL_GUARD.lock().unwrap_or_else(|e| e.into_inner());
    set_liveness_config(LivenessConfig::with_stall_after(stall_after));
    ArmedStall(g)
}

/// Like [`killed_lapply`], but the probe *hangs* the worker (silently — no
/// heartbeats) instead of killing it: element `h_i` hangs exactly once.
fn hung_lapply(
    spec: PlanSpec,
    n: i64,
    hangs: &[i64],
    retry: Option<RetryPolicy>,
    deadline: Option<Duration>,
) -> (Result<Vec<Value>, FutureError>, Vec<String>) {
    let markers: Vec<String> = hangs.iter().map(|h| marker(&format!("h{h}"))).collect();
    let out = with_plan(spec, || {
        let env = Env::new();
        let xs: Vec<Value> = (0..n).map(Value::I64).collect();
        let mut probe = Expr::lit(0i64);
        for (h, m) in hangs.iter().zip(&markers) {
            probe = Expr::if_else(
                Expr::prim(PrimOp::Eq, vec![Expr::var("x"), Expr::lit(*h)]),
                Expr::chaos_hang_once(30_000, m),
                probe,
            );
        }
        let body = Expr::seq(vec![probe, Expr::add(Expr::var("x"), Expr::runif(1))]);
        let mut opts = LapplyOpts::new().seed(99).chunking(Chunking::ChunkSize(3));
        if let Some(p) = retry {
            opts = opts.retry(p);
        }
        if let Some(d) = deadline {
            opts = opts.deadline(d);
        }
        future_lapply(&xs, "x", &body, &env, &opts)
    });
    (out, markers)
}

/// Remote backends (disposable worker processes): a worker hung mid-map is
/// declared stalled after `stall_after` of heartbeat silence and killed.
/// With retry the resubmitted chunk makes the map bit-identical to the
/// clean run; without retry the map fails with a structured recoverable
/// error — never a hang.
fn assert_midmap_hang_contract(spec: PlanSpec) {
    let _armed = arm_stall(Duration::from_millis(250));

    // Clean reference run (no hangs, same seed), under the armed detector:
    // busy-but-alive workers heartbeat and must NOT be culled.
    let (want, _) = hung_lapply(spec.clone(), 12, &[], None, None);
    let want = want.expect("clean run under armed stall detector");

    let (got, markers) = hung_lapply(spec.clone(), 12, &[4], Some(retry_policy()), None);
    cleanup(&markers);
    assert_eq!(got.expect("supervised hang run"), want, "{}: hang+retry != clean", spec.name());

    let (got, markers) = hung_lapply(spec.clone(), 12, &[4], None, None);
    cleanup(&markers);
    match got {
        Err(e) => {
            assert!(!e.is_eval(), "{}: stall kill reported as eval error: {e}", spec.name());
            assert!(e.is_recoverable(), "{}: stall kill not recoverable: {e}", spec.name());
        }
        Ok(_) => panic!("{}: hang without retry must fail the map", spec.name()),
    }
}

#[test]
fn midmap_hang_contract_multisession() {
    assert_midmap_hang_contract(PlanSpec::multiprocess(2));
}

#[test]
fn midmap_hang_contract_cluster() {
    assert_midmap_hang_contract(PlanSpec::cluster(&["n1.local", "n2.local"]));
}

#[test]
fn midmap_hang_multicore_is_bounded_by_deadline() {
    // In-process workers are threads — there is nothing to kill, so the
    // deadline plane bounds the hang instead: expiry flips the cancel
    // flag, the hang's sleep slices observe it, and the map surfaces
    // TimedOut within bounded time whether or not retry is armed
    // (timeouts are terminal, never resubmitted).
    for retry in [None, Some(retry_policy())] {
        let t0 = std::time::Instant::now();
        let (got, markers) = hung_lapply(
            PlanSpec::multicore(2),
            12,
            &[4],
            retry,
            Some(Duration::from_millis(150)),
        );
        cleanup(&markers);
        match got {
            Err(FutureError::TimedOut { elapsed, .. }) => {
                assert!(elapsed >= Duration::from_millis(150), "early timeout: {elapsed:?}");
            }
            other => panic!("expected TimedOut from a deadlined in-process hang, got {other:?}"),
        }
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "deadline did not bound the hang: {:?}",
            t0.elapsed()
        );
    }
}

#[test]
fn midmap_kill_contract_multicore() {
    assert_midmap_kill_contract(PlanSpec::multicore(2));
}

#[test]
fn midmap_kill_contract_multisession() {
    assert_midmap_kill_contract(PlanSpec::multiprocess(2));
}

#[test]
fn midmap_kill_contract_cluster() {
    assert_midmap_kill_contract(PlanSpec::cluster(&["n1.local", "n2.local"]));
}

#[test]
fn retry_counters_tick_on_supervised_recovery() {
    let before = rustures::metrics::supervision_counters();
    let (got, markers) = killed_lapply(PlanSpec::multiprocess(2), 12, &[4], Some(retry_policy()));
    cleanup(&markers);
    assert!(got.is_ok());
    let after = rustures::metrics::supervision_counters();
    assert!(after.retries > before.retries, "retry counter must tick");
    assert!(after.worker_deaths > before.worker_deaths, "death counter must tick");
}

#[test]
fn supervised_cancel_is_not_retried() {
    // Cancellation is user intent: the retry loop must stay disarmed even
    // though the worker loss it causes would otherwise be retryable.
    with_plan(PlanSpec::multiprocess(1), || {
        let env = Env::new();
        let f = future_with(
            Expr::Spin { millis: 5000 },
            &env,
            FutureOpts::new().retry(RetryPolicy::idempotent(5)),
        )
        .unwrap();
        assert!(f.cancel());
        match f.value() {
            Err(e) => assert!(e.is_recoverable(), "{e}"),
            Ok(_) => panic!("cancelled supervised future returned a value"),
        }
    });
}

#[test]
fn plan_wide_retry_supervises_unannotated_futures() {
    use rustures::api::plan::with_plan_retry;
    let m = marker("planwide");
    let out = with_plan_retry(PlanSpec::multiprocess(1), retry_policy(), || {
        let env = Env::new();
        // No per-future retry: the plan default arms supervision.
        let f = future(
            Expr::seq(vec![Expr::chaos_kill_once(&m), Expr::lit(21i64)]),
            &env,
        )
        .unwrap();
        f.value()
    });
    let _ = std::fs::remove_file(&m);
    assert_eq!(out.unwrap(), Value::I64(21));
}

#[test]
fn retry_exhaustion_has_structured_provenance() {
    with_plan(PlanSpec::multiprocess(1), || {
        let env = Env::new();
        let f = future_with(
            Expr::chaos_kill(),
            &env,
            FutureOpts::new()
                .retry(RetryPolicy::idempotent(3).with_backoff(Duration::from_millis(1), 1.0)),
        )
        .unwrap();
        match f.value() {
            Err(FutureError::Retried { attempts, last }) => {
                assert_eq!(attempts, 3);
                assert!(last.is_recoverable());
            }
            other => panic!("expected Retried, got {other:?}"),
        }
    });
}

#[test]
fn cancelled_future_surfaces_as_recoverable_error() {
    with_plan(PlanSpec::multiprocess(1), || {
        let env = Env::new();
        let f = future(Expr::Spin { millis: 5000 }, &env).unwrap();
        assert!(f.cancel(), "cancel should succeed on a running future");
        match f.value() {
            Err(e) => {
                assert!(!e.is_eval(), "cancellation is not an eval error");
                assert!(e.is_recoverable(), "cancellation should be recoverable: {e}");
            }
            Ok(_) => panic!("cancelled future returned a value"),
        }
    });
}

#[test]
fn pool_recovers_capacity_after_cancel() {
    with_plan(PlanSpec::multiprocess(1), || {
        let env = Env::new();
        let f = future(Expr::Spin { millis: 5000 }, &env).unwrap();
        assert!(f.cancel());
        let _ = f.value();
        // The single worker was killed; a new future must still run
        // (capacity respawns on demand).
        let g = future(Expr::lit(7i64), &env).unwrap();
        assert_eq!(g.value().unwrap(), Value::I64(7));
    });
}

#[test]
fn retry_pattern_relaunches_after_failure() {
    // The paper's retry({...}, times = 3, on = "FutureError") sketch.
    with_plan(PlanSpec::multiprocess(1), || {
        let env = Env::new();
        let mut attempts = 0;
        let v = loop {
            attempts += 1;
            let f = future(Expr::lit(42i64), &env).unwrap();
            if attempts == 1 {
                // Inject a failure on the first attempt.
                f.cancel();
            }
            match f.value() {
                Ok(v) => break v,
                Err(e) if e.is_recoverable() && attempts < 3 => continue,
                Err(e) => panic!("unrecoverable: {e}"),
            }
        };
        assert_eq!(v, Value::I64(42));
        assert_eq!(attempts, 2, "should have recovered on the second attempt");
    });
}

#[test]
fn batch_job_cancelled_before_start() {
    with_plan(PlanSpec::Batch { workers: 1, submit_latency_ms: 200, poll_interval_ms: 2 }, || {
        let env = Env::new();
        let f = future(Expr::lit(1i64), &env).unwrap();
        // Cancel while still pending (200ms submit latency guarantees it).
        assert!(f.cancel());
        match f.value() {
            Err(e) => assert!(e.is_recoverable(), "{e}"),
            Ok(_) => panic!("cancelled batch job returned a value"),
        }
    });
}

#[test]
fn eval_error_is_not_recoverable_but_future_error_is() {
    with_plan(PlanSpec::multicore(1), || {
        let env = Env::new();
        let f = future(Expr::stop(Expr::lit("user bug")), &env).unwrap();
        let e = f.value().unwrap_err();
        assert!(e.is_eval());
        assert!(!e.is_recoverable());
    });
}

#[test]
fn missing_global_is_neither_eval_nor_recoverable() {
    with_plan(PlanSpec::sequential(), || {
        let env = Env::new();
        let e = future(Expr::var("ghost"), &env).unwrap_err();
        assert!(!e.is_eval());
        assert!(!e.is_recoverable(), "missing global retries cannot succeed");
    });
}

#[test]
fn restart_relaunches_a_cancelled_future() {
    // The paper's restart(f) future-work item, implemented.
    with_plan(PlanSpec::multiprocess(1), || {
        let mut env = Env::new();
        env.insert("x", 21i64);
        let f = future_with(
            Expr::mul(Expr::var("x"), Expr::lit(2i64)),
            &env,
            FutureOpts::new().restartable(),
        )
        .unwrap();
        f.cancel();
        let first = f.value();
        assert!(first.is_err(), "cancelled run should fail");
        f.restart().unwrap();
        assert_eq!(f.value().unwrap(), Value::I64(42));
    });
}

#[test]
fn restart_requires_opt_in() {
    with_plan(PlanSpec::sequential(), || {
        let env = Env::new();
        let f = future(Expr::lit(1i64), &env).unwrap();
        assert!(f.restart().is_err());
    });
}
