//! Liveness plane integration: per-future deadlines (including the queued
//! dispatcher path), cooperative cancellation edge cases, stall detection
//! returning a hung worker's seat to the capacity ledger, and stale-result
//! fencing of delayed frames at the batch scheduler.

use std::sync::Mutex;
use std::time::{Duration, Instant};

use rustures::api::plan::{with_plan, PlanSpec};
use rustures::api::session::Session;
use rustures::liveness::{reset_liveness_config, set_liveness_config, LivenessConfig};
use rustures::prelude::*;

/// Tests that arm the process-wide stall detector serialize through this
/// guard; the config resets when the guard drops (panic-safe).
static STALL_GUARD: Mutex<()> = Mutex::new(());

struct ArmedStall(#[allow(dead_code)] std::sync::MutexGuard<'static, ()>);

impl Drop for ArmedStall {
    fn drop(&mut self) {
        reset_liveness_config();
    }
}

fn arm_stall(stall_after: Duration) -> ArmedStall {
    let g = STALL_GUARD.lock().unwrap_or_else(|e| e.into_inner());
    set_liveness_config(LivenessConfig::with_stall_after(stall_after));
    ArmedStall(g)
}

fn marker(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("rustures-lv-{tag}-{}", rustures::util::uuid_v4()))
        .to_string_lossy()
        .into_owned()
}

#[test]
fn deadline_shorter_than_queue_wait_times_out_queued_future() {
    // One seat, occupied.  The queued future's deadline expires while it is
    // still waiting for admission — the clock covers queue wait, and the
    // cancelled cell must never reach the seat.
    with_plan(PlanSpec::multicore(1), || {
        let env = Env::new();
        let busy = future(Expr::Sleep { millis: 400 }, &env).unwrap();
        let f = future_with(
            Expr::Sleep { millis: 400 },
            &env,
            FutureOpts::new().queued().deadline(Duration::from_millis(60)),
        )
        .unwrap();
        let t0 = Instant::now();
        match f.value() {
            Err(FutureError::TimedOut { elapsed, .. }) => {
                assert!(elapsed >= Duration::from_millis(60), "short-changed: {elapsed:?}");
                assert!(
                    t0.elapsed() < Duration::from_millis(350),
                    "timeout must fire during the queue wait, not after admission: {:?}",
                    t0.elapsed()
                );
            }
            other => panic!("expected TimedOut, got {other:?}"),
        }
        busy.value().unwrap();
        // The seat serves the next future immediately — the timed-out cell
        // was skipped by the dispatcher, not left squatting.
        let g = future(Expr::lit(3i64), &env).unwrap();
        assert_eq!(g.value().unwrap(), Value::I64(3));
    });
}

#[test]
fn already_expired_deadline_times_out_despite_inflight_serialization() {
    // A deadline that expires while the (large) payload is still being
    // shipped / evaluated: collection must surface TimedOut promptly
    // rather than ride out the transfer, and the seat must recover.
    with_plan(PlanSpec::multiprocess(1), || {
        let mut env = Env::new();
        let n = 256 * 256;
        env.insert("t", Tensor::new(vec![256, 256], vec![1.0f32; n]).unwrap());
        let f = future_with(
            Expr::seq(vec![
                Expr::prim(PrimOp::Sum, vec![Expr::var("t")]),
                Expr::Sleep { millis: 400 },
            ]),
            &env,
            FutureOpts::new().deadline(Duration::from_nanos(1)),
        )
        .unwrap();
        let t0 = Instant::now();
        match f.value() {
            Err(FutureError::TimedOut { .. }) => {}
            other => panic!("expected TimedOut, got {other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_millis(300), "timeout lagged: {:?}", t0.elapsed());
        let g = future(Expr::lit(5i64), &env).unwrap();
        assert_eq!(g.value().unwrap(), Value::I64(5));
    });
}

#[test]
fn cancel_after_resolve_is_a_noop() {
    with_plan(PlanSpec::multiprocess(1), || {
        let env = Env::new();
        let f = future(Expr::lit(9i64), &env).unwrap();
        let give_up = Instant::now() + Duration::from_secs(10);
        while !f.resolved() {
            assert!(Instant::now() < give_up, "future never resolved");
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(!f.cancel(), "cancel after resolution must report false");
        assert_eq!(f.value().unwrap(), Value::I64(9), "value must survive the late cancel");
    });
}

#[test]
fn deadline_is_inert_on_a_fast_map() {
    // A generous deadline on work that finishes early must never fire.
    with_plan(PlanSpec::multicore(2), || {
        let env = Env::new();
        let xs: Vec<Value> = (0..6i64).map(Value::I64).collect();
        let body = Expr::add(Expr::var("x"), Expr::runif(1));
        let got = future_lapply(
            &xs,
            "x",
            &body,
            &env,
            &LapplyOpts::new()
                .seed(7)
                .chunking(Chunking::ChunkSize(2))
                .deadline(Duration::from_secs(60)),
        )
        .unwrap();
        let want = future_lapply(
            &xs,
            "x",
            &body,
            &env,
            &LapplyOpts::new().seed(7).chunking(Chunking::ChunkSize(2)),
        )
        .unwrap();
        assert_eq!(got, want, "a deadline that never fires must not perturb results");
    });
}

#[test]
fn stale_frame_from_superseded_attempt_is_fenced_even_when_delayed() {
    // A job whose result frame (attempt epoch 2) lands only after a delay,
    // into a slot expecting epoch 5: the daemon must fence it on harvest —
    // Failed, file deleted, counter bumped — never surface it.
    use rustures::ipc::wire::encode_message;
    use rustures::ipc::{Message, TaskOpts, TaskSpec};
    use rustures::scheduler::{JobState, SchedConfig, Scheduler};

    let sched = Scheduler::start(SchedConfig {
        submit_latency: Duration::from_millis(1),
        ..SchedConfig::local(1)
    })
    .unwrap();
    let session = 88_000_011u64;
    let before = rustures::metrics::session_supervision_counters(session).fenced_results;

    let task = TaskSpec {
        id: "fence-delayed".into(),
        expr: Expr::Sleep { millis: 150 },
        globals: Env::new(),
        opts: TaskOpts { attempt: 2, ..TaskOpts::default() },
    };
    let task_file = sched.spool().join("fence-delayed.task");
    std::fs::write(&task_file, encode_message(&Message::Task(task))).unwrap();
    let job = sched.submit_attempt(task_file, session, 5);

    let give_up = Instant::now() + Duration::from_secs(20);
    let detail = loop {
        match sched.poll(job) {
            Some(JobState::Failed(detail)) => break detail,
            Some(JobState::Completed) => panic!("stale frame surfaced as a completed job"),
            Some(JobState::Cancelled) | None => panic!("fence probe lost its job"),
            _ => {
                assert!(Instant::now() < give_up, "fence probe never reached a terminal state");
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    };
    assert!(
        detail.contains("fenced stale result"),
        "expected a fencing failure, got: {detail}"
    );
    let file_left = sched.result_file(job).is_some_and(|p| p.exists());
    sched.shutdown();
    assert!(!file_left, "fenced result file must be deleted");
    let after = rustures::metrics::session_supervision_counters(session).fenced_results;
    assert!(after > before, "fenced_results must tick: {before} -> {after}");
}

#[test]
fn hung_worker_seat_returns_to_ledger() {
    // Acceptance: a worker hung mid-lapply is killed by the stall detector
    // and its seat returns through the ledger — after the (retried) map
    // completes, the session holds zero execution-slot leases, in both the
    // programmatic accounting and the capacity_json surface.
    let _armed = arm_stall(Duration::from_millis(250));
    let s = Session::with_plan(PlanSpec::multiprocess(2));
    let sid = s.id();
    let env = Env::new();
    let xs: Vec<Value> = (0..12i64).map(Value::I64).collect();
    let m = marker("seat");
    let body = Expr::seq(vec![
        Expr::if_else(
            Expr::prim(PrimOp::Eq, vec![Expr::var("x"), Expr::lit(3i64)]),
            Expr::chaos_hang_once(60_000, &m),
            Expr::lit(0i64),
        ),
        Expr::add(Expr::var("x"), Expr::runif(1)),
    ]);
    let opts = LapplyOpts::new()
        .seed(41)
        .chunking(Chunking::ChunkSize(3))
        .retry(RetryPolicy::idempotent(4).with_backoff(Duration::from_millis(1), 2.0));
    let got = s.lapply(&xs, "x", &body, &env, &opts);
    let _ = std::fs::remove_file(&m);
    got.expect("hang + stall kill + retry must complete the map");

    assert_eq!(
        rustures::capacity::session_in_use(sid),
        0,
        "hung worker's lease leaked past the stall kill"
    );
    let json = rustures::util::json::parse(&rustures::metrics::capacity_json()).unwrap();
    let sessions = json.get("sessions").unwrap().as_arr().unwrap();
    let entry = sessions
        .iter()
        .find(|e| e.get("session").and_then(|v| v.as_i64()) == Some(sid as i64))
        .expect("session missing from capacity_json");
    assert_eq!(
        entry.get("in_use").unwrap().as_i64(),
        Some(0),
        "capacity_json shows a leaked in_use lease"
    );

    // The stall registered in the session's liveness counters.
    let c = rustures::metrics::session_supervision_counters(sid);
    assert!(c.stalls >= 1, "stall kill must be counted, got {c:?}");
    s.close();
}

#[test]
fn session_default_deadline_is_a_collection_side_default() {
    // The session-level default applies to futures created without an
    // explicit deadline and is overridden per future.
    let s = Session::with_plan(PlanSpec::multicore(1));
    s.set_default_deadline(Some(Duration::from_millis(60)));
    let env = Env::new();
    s.scope(|sess| {
        let slow = sess.future(Expr::Sleep { millis: 60_000 }, &env).unwrap();
        match slow.value() {
            Err(FutureError::TimedOut { .. }) => {}
            other => panic!("session default deadline must apply, got {other:?}"),
        }
        let generous = sess
            .future_with(
                Expr::Sleep { millis: 80 },
                &env,
                FutureOpts::new().deadline(Duration::from_secs(30)),
            )
            .unwrap();
        assert!(generous.value().is_ok(), "per-future deadline must override the default");
    });
    s.close();
}
