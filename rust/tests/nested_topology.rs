//! E6: nested parallelism and the built-in protection against it.
//!
//! The paper: without protection, PkgA×PkgB would run N² workers; with it,
//! nested levels default to sequential unless the end-user configures
//! `plan(list(...))` — then layer capacities multiply as configured.

use rustures::api::plan::{current_topology, with_plan_topology, PlanSpec};
use rustures::prelude::*;

#[test]
fn nested_futures_default_to_sequential_inside_workers() {
    // A chunked lapply whose chunks each evaluate elements sequentially:
    // depth-1 futures are created on the worker by the chunk's evaluation.
    // With a single-level plan, the shipped nested topology must be empty
    // (⇒ implicit sequential on workers), and the run must complete.
    with_plan_topology(vec![PlanSpec::multiprocess(2)], || {
        let env = Env::new();
        let xs: Vec<Value> = (0..6i64).map(Value::I64).collect();
        let out = future_lapply(
            &xs,
            "x",
            &Expr::mul(Expr::var("x"), Expr::lit(2i64)),
            &env,
            &LapplyOpts::new(),
        )
        .unwrap();
        assert_eq!(out.len(), 6);
    });
}

#[test]
fn topology_defaults_and_tweaks() {
    // plan(list(tweak(multisession, 2), tweak(multisession, 3))) → 2×3.
    with_plan_topology(
        vec![PlanSpec::multiprocess(2), PlanSpec::multiprocess(3)],
        || {
            let topo = current_topology();
            assert_eq!(topo.len(), 2);
            assert_eq!(topo[0].effective_workers(), 2);
            assert_eq!(topo[1].effective_workers(), 3);
        },
    );
}

#[test]
fn nested_plan_ships_remaining_topology_to_tasks() {
    // The TaskSpec carries topology[d+1..]; verify through the public API by
    // inspecting what the backend at depth 0 receives.
    use rustures::api::plan::{at_depth, backend_for_current_depth};
    with_plan_topology(
        vec![PlanSpec::sequential(), PlanSpec::multicore(3), PlanSpec::sequential()],
        || {
            let (_b0, nested0) = backend_for_current_depth().unwrap();
            assert_eq!(
                nested0,
                vec![PlanSpec::multicore(3), PlanSpec::sequential()],
                "depth 0 ships the rest"
            );
            at_depth(1, || {
                let (b1, nested1) = backend_for_current_depth().unwrap();
                assert_eq!(b1.name(), "multicore");
                assert_eq!(nested1, vec![PlanSpec::sequential()]);
            });
            at_depth(5, || {
                // Beyond the topology: implicit sequential, nothing nested.
                let (b5, nested5) = backend_for_current_depth().unwrap();
                assert_eq!(b5.name(), "sequential");
                assert!(nested5.is_empty());
            });
        },
    );
}

#[test]
fn two_layer_topology_runs_nested_lapply() {
    // Outer layer: 2 thread workers; inner layer: sequential (protection).
    // The inner "parallelism" is expressed via chunked evaluation inside
    // each outer future.
    with_plan_topology(vec![PlanSpec::multicore(2), PlanSpec::sequential()], || {
        let env = Env::new();
        let xs: Vec<Value> = (0..4i64).map(Value::I64).collect();
        // Each outer element computes sum(x*1 .. x*3) through a list expr.
        let body = Expr::prim(
            rustures::api::expr::PrimOp::Sum,
            vec![Expr::list(vec![
                Expr::mul(Expr::var("x"), Expr::lit(1i64)),
                Expr::mul(Expr::var("x"), Expr::lit(2i64)),
                Expr::mul(Expr::var("x"), Expr::lit(3i64)),
            ])],
        );
        let out = future_lapply(&xs, "x", &body, &env, &LapplyOpts::new()).unwrap();
        assert_eq!(
            out,
            vec![Value::F64(0.0), Value::F64(6.0), Value::F64(12.0), Value::F64(18.0)]
        );
    });
}

#[test]
fn implicit_sequential_beyond_topology_depth() {
    // plan(list(multisession, multisession)) effectively equals
    // plan(list(multisession, sequential)) when nested protection applies
    // to deeper levels (paper: "plan(sequential) is implicit").
    use rustures::api::plan::{at_depth, backend_for_current_depth};
    with_plan_topology(vec![PlanSpec::multicore(2)], || {
        at_depth(1, || {
            let (b, _) = backend_for_current_depth().unwrap();
            assert_eq!(b.name(), "sequential");
        });
        at_depth(2, || {
            let (b, _) = backend_for_current_depth().unwrap();
            assert_eq!(b.name(), "sequential");
        });
    });
}
