//! Property-based tests on coordinator invariants (proptest_lite — the
//! offline stand-in for proptest; see DESIGN.md §Substitutions).
//!
//! Invariants: wire-format roundtrips for arbitrary values/expressions,
//! chunk partitions (cover/disjoint/balanced), globals analysis vs a naive
//! reference, RNG stream algebra, and env capture snapshots.

use std::sync::Arc;

use rustures::api::env::Env;
use rustures::api::expr::{Expr, PrimOp};
use rustures::api::globals::free_variables;
use rustures::api::rng::RngStream;
use rustures::api::value::{Tensor, Value};
use rustures::ipc::wire::{dec_expr, dec_value, enc_expr, enc_value, Decoder, Encoder};
use rustures::mapreduce::{chunk_count, partition, Chunking};
use rustures::proptest_lite::{check, Gen};

// ------------------------------------------------------------ generators

fn gen_value(g: &mut Gen, depth: usize) -> Value {
    match g.usize_in(0, if depth == 0 { 5 } else { 6 }) {
        0 => Value::Unit,
        1 => Value::Bool(g.bool()),
        2 => Value::I64(g.u64() as i64),
        3 => Value::F64(g.f64_in(-1e6, 1e6)),
        4 => Value::Str(g.ident()),
        5 => {
            let n = g.usize_in(0, 8);
            let data: Vec<f32> = (0..n).map(|_| g.f64_in(-10.0, 10.0) as f32).collect();
            Value::Tensor(Tensor::new(vec![n], data).unwrap())
        }
        _ => {
            let n = g.usize_in(0, 3);
            Value::List((0..n).map(|_| gen_value(g, depth - 1)).collect())
        }
    }
}

fn gen_expr(g: &mut Gen, depth: usize) -> Expr {
    if depth == 0 {
        return match g.usize_in(0, 1) {
            0 => Expr::lit(gen_value(g, 1)),
            _ => Expr::var(&g.ident()),
        };
    }
    match g.usize_in(0, 10) {
        0 => Expr::lit(gen_value(g, 1)),
        1 => Expr::var(&g.ident()),
        2 => Expr::let_in(&g.ident(), gen_expr(g, depth - 1), gen_expr(g, depth - 1)),
        3 => Expr::seq((0..g.usize_in(1, 3)).map(|_| gen_expr(g, depth - 1)).collect()),
        4 => Expr::list((0..g.usize_in(0, 3)).map(|_| gen_expr(g, depth - 1)).collect()),
        5 => Expr::prim(
            *g.choose(&[PrimOp::Add, PrimOp::Sub, PrimOp::Mul, PrimOp::Div, PrimOp::Sum]),
            vec![gen_expr(g, depth - 1), gen_expr(g, depth - 1)],
        ),
        6 => Expr::if_else(
            gen_expr(g, depth - 1),
            gen_expr(g, depth - 1),
            gen_expr(g, depth - 1),
        ),
        7 => Expr::dyn_lookup(gen_expr(g, depth - 1)),
        8 => Expr::call(&g.ident(), vec![gen_expr(g, depth - 1)]),
        9 => {
            let n = g.usize_in(0, 4);
            Expr::map_chunk(
                &g.ident(),
                Arc::new(gen_expr(g, depth - 1)),
                (0..n).map(|_| gen_value(g, 1)).collect(),
                g.u64() % 10_000,
            )
        }
        _ => Expr::with_rng_stream(g.u64() % 1000, gen_expr(g, depth - 1)),
    }
}

// ------------------------------------------------------------ properties

#[test]
fn prop_value_wire_roundtrip() {
    check("value-wire-roundtrip", 200, |g| {
        let v = gen_value(g, 3);
        let mut e = Encoder::new();
        enc_value(&mut e, &v);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        let back = dec_value(&mut d).map_err(|e| e.to_string())?;
        if back != v {
            return Err(format!("roundtrip mismatch: {v:?} vs {back:?}"));
        }
        if !d.finished() {
            return Err("trailing bytes".into());
        }
        Ok(())
    });
}

#[test]
fn prop_expr_wire_roundtrip() {
    check("expr-wire-roundtrip", 200, |g| {
        let expr = gen_expr(g, 4);
        let mut e = Encoder::new();
        enc_expr(&mut e, &expr);
        let bytes = e.into_bytes();
        let back = dec_expr(&mut Decoder::new(&bytes)).map_err(|e| e.to_string())?;
        if back != expr {
            return Err("expr roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_large_tensor_wire_roundtrip() {
    // The bulk (single-memcpy) tensor encode/decode path at realistic
    // payload sizes: 16 KiB – 1 MiB buffers, exact f32 bit preservation.
    check("large-tensor-wire-roundtrip", 10, |g| {
        let n = g.usize_in(1 << 12, 1 << 18);
        let seed = g.u64();
        let data: Vec<f32> = (0..n)
            .map(|i| {
                let bits = rustures::util::uuid::splitmix64(seed ^ i as u64);
                // Bounded, always-finite values (NaN would break `==`).
                ((bits % 200_001) as f32 - 100_000.0) * 0.25
            })
            .collect();
        let v = Value::Tensor(Tensor::new(vec![n], data).unwrap());
        let mut e = Encoder::new();
        enc_value(&mut e, &v);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        let back = dec_value(&mut d).map_err(|e| e.to_string())?;
        if back != v {
            return Err(format!("large tensor roundtrip mismatch at n={n}"));
        }
        if !d.finished() {
            return Err("trailing bytes".into());
        }
        Ok(())
    });
}

#[test]
fn prop_map_chunk_wire_roundtrip() {
    // The new chunk encoding: body once + packed elements (incl. tensors).
    check("map-chunk-wire-roundtrip", 100, |g| {
        let body = Arc::new(gen_expr(g, 3));
        let n = g.usize_in(0, 12);
        let elements: Vec<Value> = (0..n).map(|_| gen_value(g, 2)).collect();
        let chunk = Expr::map_chunk(&g.ident(), body, elements, g.u64() % 1_000_000);
        let mut e = Encoder::new();
        enc_expr(&mut e, &chunk);
        let bytes = e.into_bytes();
        let mut d = Decoder::new(&bytes);
        let back = dec_expr(&mut d).map_err(|e| e.to_string())?;
        if back != chunk {
            return Err("map-chunk roundtrip mismatch".into());
        }
        if !d.finished() {
            return Err("trailing bytes".into());
        }
        Ok(())
    });
}

#[test]
fn prop_partition_covers_disjoint_balanced() {
    check("partition-invariants", 300, |g| {
        let n = g.usize_in(0, 500);
        let chunks = g.usize_in(1, 64);
        let parts = partition(n, chunks);
        let mut covered = Vec::new();
        for r in &parts {
            covered.extend(r.clone());
        }
        if covered != (0..n).collect::<Vec<_>>() {
            return Err(format!("not a cover: n={n} chunks={chunks}"));
        }
        if n > 0 {
            let sizes: Vec<usize> = parts.iter().map(|r| r.len()).collect();
            let min = sizes.iter().min().unwrap();
            let max = sizes.iter().max().unwrap();
            if max - min > 1 {
                return Err(format!("unbalanced: {sizes:?}"));
            }
            if sizes.iter().any(|s| *s == 0) {
                return Err("empty chunk".into());
            }
        }
        Ok(())
    });
}

#[test]
fn prop_chunk_count_bounds() {
    check("chunk-count-bounds", 300, |g| {
        let n = g.usize_in(0, 1000);
        let workers = g.usize_in(1, 32);
        let policy = match g.usize_in(0, 3) {
            0 => Chunking::PerElement,
            1 => Chunking::PerWorker,
            2 => Chunking::Scheduling(g.f64_in(0.1, 8.0)),
            _ => Chunking::ChunkSize(g.usize_in(1, 50)),
        };
        let c = chunk_count(n, workers, policy);
        if n == 0 && c != 0 {
            return Err("n=0 must give 0 chunks".into());
        }
        if n > 0 && (c < 1 || c > n) {
            return Err(format!("chunk count {c} out of [1, {n}]"));
        }
        Ok(())
    });
}

/// Naive reference implementation of free-variable analysis using explicit
/// substitution of bound names.
fn naive_free_vars(expr: &Expr, bound: &mut Vec<String>, out: &mut Vec<String>) {
    match expr {
        Expr::Var(n) => {
            if !bound.contains(n) && !out.contains(n) {
                out.push(n.clone());
            }
        }
        Expr::Let { name, value, body } => {
            naive_free_vars(value, bound, out);
            bound.push(name.clone());
            naive_free_vars(body, bound, out);
            bound.pop();
        }
        Expr::Seq(items) | Expr::List(items) => {
            for i in items {
                naive_free_vars(i, bound, out);
            }
        }
        Expr::Index { list, index } => {
            naive_free_vars(list, bound, out);
            naive_free_vars(index, bound, out);
        }
        Expr::Call { args, .. } | Expr::Prim { args, .. } => {
            for a in args {
                naive_free_vars(a, bound, out);
            }
        }
        Expr::If { cond, then, otherwise } => {
            naive_free_vars(cond, bound, out);
            naive_free_vars(then, bound, out);
            naive_free_vars(otherwise, bound, out);
        }
        Expr::DynLookup(i) | Expr::Stop(i) => naive_free_vars(i, bound, out),
        Expr::Emit { message, .. } => naive_free_vars(message, bound, out),
        Expr::WithRngStream { body, .. } => naive_free_vars(body, bound, out),
        Expr::MapChunk { param, body, .. } => {
            bound.push(param.clone());
            naive_free_vars(body, bound, out);
            bound.pop();
        }
        Expr::Lit(_)
        | Expr::Rng { .. }
        | Expr::Spin { .. }
        | Expr::Sleep { .. }
        | Expr::Work { .. }
        | Expr::ChaosKill { .. }
        | Expr::ChaosHang { .. }
        | Expr::Await { .. } => {}
    }
}

#[test]
fn prop_globals_analysis_matches_naive_reference() {
    check("globals-vs-naive", 300, |g| {
        let expr = gen_expr(g, 4);
        let got = free_variables(&expr);
        let mut want = Vec::new();
        naive_free_vars(&expr, &mut Vec::new(), &mut want);
        if got != want {
            return Err(format!("free vars {got:?} != naive {want:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_rng_jump_composition() {
    // nth_stream(s, a+b) == next_stream applied b times to nth_stream(s, a)
    check("rng-jump-composition", 30, |g| {
        let seed = g.u64();
        let a = g.usize_in(0, 20) as u64;
        let b = g.usize_in(0, 5) as u64;
        let direct = RngStream::nth_stream(seed, a + b);
        let mut stepped = RngStream::nth_stream(seed, a);
        for _ in 0..b {
            stepped = stepped.next_stream();
        }
        if direct != stepped {
            return Err(format!("jump composition broken at seed={seed} a={a} b={b}"));
        }
        Ok(())
    });
}

#[test]
fn prop_env_subset_snapshot_independence() {
    check("env-snapshot", 200, |g| {
        let mut env = Env::new();
        let names: Vec<String> = (0..g.usize_in(1, 6)).map(|_| g.ident()).collect();
        for n in &names {
            env.insert(n, Value::I64(g.u64() as i64));
        }
        let snap = env.subset(&names);
        // Mutate originals; snapshot unaffected.
        let before: Vec<Option<Value>> = names.iter().map(|n| snap.get(n).cloned()).collect();
        for n in &names {
            env.insert(n, Value::Str("mutated".into()));
        }
        let after: Vec<Option<Value>> = names.iter().map(|n| snap.get(n).cloned()).collect();
        if before != after {
            return Err("snapshot changed after env mutation".into());
        }
        Ok(())
    });
}

/// Like [`gen_expr`] but never generates `DynLookup` — the one construct
/// the optimistic static analysis is documented NOT to see through.
fn gen_sound_expr(g: &mut Gen, depth: usize) -> Expr {
    if depth == 0 {
        return match g.usize_in(0, 1) {
            0 => Expr::lit(gen_value(g, 1)),
            _ => Expr::var(&g.ident()),
        };
    }
    match g.usize_in(0, 10) {
        0 => Expr::lit(gen_value(g, 1)),
        1 => Expr::var(&g.ident()),
        2 => Expr::let_in(&g.ident(), gen_sound_expr(g, depth - 1), gen_sound_expr(g, depth - 1)),
        3 => Expr::seq((0..g.usize_in(1, 3)).map(|_| gen_sound_expr(g, depth - 1)).collect()),
        4 => Expr::list((0..g.usize_in(0, 3)).map(|_| gen_sound_expr(g, depth - 1)).collect()),
        5 => Expr::prim(
            *g.choose(&[PrimOp::Add, PrimOp::Sub, PrimOp::Mul, PrimOp::Div, PrimOp::Sum]),
            vec![gen_sound_expr(g, depth - 1), gen_sound_expr(g, depth - 1)],
        ),
        6 => Expr::if_else(
            gen_sound_expr(g, depth - 1),
            gen_sound_expr(g, depth - 1),
            gen_sound_expr(g, depth - 1),
        ),
        7 => Expr::index(gen_sound_expr(g, depth - 1), gen_sound_expr(g, depth - 1)),
        8 => Expr::call(&g.ident(), vec![gen_sound_expr(g, depth - 1)]),
        9 => {
            let n = g.usize_in(0, 4);
            Expr::map_chunk(
                &g.ident(),
                Arc::new(gen_sound_expr(g, depth - 1)),
                (0..n).map(|_| gen_value(g, 1)).collect(),
                g.u64() % 10_000,
            )
        }
        _ => Expr::with_rng_stream(g.u64() % 1000, gen_sound_expr(g, depth - 1)),
    }
}

#[test]
fn prop_eval_lookups_outside_dyn_lookup_contained_in_free_variables() {
    // The analysis-soundness contract from api/globals.rs, machine-checked:
    // bind exactly `free_variables(expr)` in the env and evaluate — no
    // variable lookup may miss.  Evaluation is allowed to fail for other
    // reasons (type errors, unknown kernels, out-of-bounds indexing,
    // Stop), but never with an "object ... not found" lookup failure,
    // because every reachable `Var` outside `DynLookup` is in the free set.
    use rustures::api::conditions::CaptureBuffer;
    use rustures::worker::eval::{evaluate, EvalCtx, RngCtx};
    check("eval-lookups-in-free-vars", 250, |g| {
        let expr = gen_sound_expr(g, 4);
        let mut env = Env::new();
        for name in free_variables(&expr) {
            env.insert(&name, Value::I64(1));
        }
        let mut buf = CaptureBuffer::new();
        let mut ctx = EvalCtx {
            buffer: &mut buf,
            rng: RngCtx::new(Some(1), 0),
            kernels: None,
            on_immediate: None,
            liveness: None,
            on_tick: None,
        };
        match evaluate(&expr, &env, &mut ctx) {
            Ok(_) => Ok(()),
            Err(e) if e.message.starts_with("object '") && e.message.contains("' not found") => {
                Err(format!("lookup escaped free-variable analysis: {e:?} in {expr:?}"))
            }
            Err(_) => Ok(()),
        }
    });
}

#[test]
fn prop_export_estimate_dominates_wire_encoding() {
    // The export-size lint's contract: the static estimator may over-count
    // but never under-counts what the wire layer would actually ship —
    // expression tree (including DynLookup, chaos markers, packed chunk
    // elements) plus the captured globals.
    check("export-estimate-dominates", 200, |g| {
        let expr = gen_expr(g, 4);
        let mut env = Env::new();
        let mut globals_wire = 0usize;
        for _ in 0..g.usize_in(0, 4) {
            let name = g.ident();
            if env.contains(&name) {
                continue; // keep the byte tally aligned with the env
            }
            let value = gen_value(g, 2);
            let mut e = Encoder::new();
            enc_value(&mut e, &value);
            // Over-states the v6 name framing (varint length, ≤ 4 bytes
            // here) — fine, the estimator only has to dominate.
            globals_wire += 4 + name.len() + e.into_bytes().len();
            env.insert(&name, value);
        }
        let mut e = Encoder::new();
        enc_expr(&mut e, &expr);
        let wire = e.into_bytes().len() + globals_wire;
        let est = rustures::analysis::estimate_export_size(&expr, &env);
        if est < wire {
            return Err(format!("estimate {est} under-counts wire {wire} for {expr:?}"));
        }
        Ok(())
    });
}

// ------------------------------------------------- v6 frame robustness

fn gen_digest(g: &mut Gen) -> rustures::ipc::intern::Digest {
    let mut out = [0u8; 16];
    out[..8].copy_from_slice(&g.u64().to_le_bytes());
    out[8..].copy_from_slice(&g.u64().to_le_bytes());
    rustures::ipc::intern::Digest(out)
}

fn gen_condition(g: &mut Gen) -> rustures::api::conditions::Condition {
    use rustures::api::conditions::{Condition, ConditionKind};
    Condition {
        kind: *g.choose(&[ConditionKind::Message, ConditionKind::Warning, ConditionKind::Immediate]),
        message: g.ident(),
        seq: g.u64() % 1000,
    }
}

/// One arbitrary [`Message`], cycling through every frame kind (the
/// `variant` selector is driven by the iteration counter upstream so all
/// eleven kinds are exercised, not just whichever the RNG favors).
fn gen_message(g: &mut Gen, variant: usize) -> rustures::ipc::Message {
    use rustures::api::conditions::Captured;
    use rustures::api::error::EvalError;
    use rustures::ipc::{
        Message, TaskMetrics, TaskOpts, TaskOutcome, TaskResult, TaskSpec,
    };
    match variant % 11 {
        0 => Message::Hello { worker_id: g.ident(), version: g.u64() as u32 % 1000 },
        1 => {
            let mut globals = Env::new();
            for _ in 0..g.usize_in(0, 3) {
                globals.insert(&g.ident(), gen_value(g, 2));
            }
            if g.bool() {
                // A compressible payload large enough to trip the codec.
                globals.insert("big", Value::Tensor(Tensor::zeros(&[g.usize_in(512, 2048)])));
            }
            Message::Task(TaskSpec {
                id: g.ident(),
                expr: gen_expr(g, 3),
                globals,
                opts: TaskOpts {
                    seed: if g.bool() { Some(g.u64()) } else { None },
                    stream_index: g.u64() % 100,
                    attempt: g.u64() as u32 % 4,
                    ..TaskOpts::default()
                },
            })
        }
        2 => Message::Immediate { task_id: g.ident(), condition: gen_condition(g) },
        3 => Message::Result(TaskResult {
            id: g.ident(),
            outcome: if g.bool() {
                TaskOutcome::Ok(gen_value(g, 3))
            } else {
                TaskOutcome::Err(EvalError {
                    message: g.ident(),
                    call: if g.bool() { Some(g.ident()) } else { None },
                })
            },
            captured: Captured {
                stdout: g.ident(),
                conditions: (0..g.usize_in(0, 3)).map(|_| gen_condition(g)).collect(),
                rng_used: g.bool(),
            },
            metrics: TaskMetrics { started_ns: g.u64(), finished_ns: g.u64() },
            attempt: g.u64() as u32 % 4,
        }),
        4 => Message::Shutdown,
        5 => Message::Ping,
        6 => Message::Pong,
        7 => Message::Heartbeat { task_id: g.ident() },
        8 => Message::Cancel { task_id: g.ident() },
        9 => Message::NeedBlob {
            digests: (0..g.usize_in(0, 3)).map(|_| gen_digest(g)).collect(),
        },
        _ => Message::Blob {
            digest: gen_digest(g),
            bytes: if g.bool() {
                Some((0..g.usize_in(0, 64)).map(|_| g.u64() as u8).collect())
            } else {
                None
            },
        },
    }
}

#[test]
fn prop_decoder_rejects_every_truncated_prefix() {
    // A strict prefix of a valid frame must decode to a clean error —
    // never a panic, never a bogus success (the header's body length can
    // no longer match the remaining bytes).
    use rustures::ipc::wire::{decode_message, encode_message};
    let variant = std::cell::Cell::new(0usize);
    check("decoder-truncation", 120, |g| {
        let msg = gen_message(g, variant.get());
        variant.set(variant.get() + 1);
        let frame = encode_message(&msg);
        // Every short frame fully; long frames at sampled cut points.
        let cuts: Vec<usize> = if frame.len() <= 64 {
            (0..frame.len()).collect()
        } else {
            (0..64).map(|i| i * frame.len() / 64).collect()
        };
        for cut in cuts {
            if decode_message(&frame[..cut]).is_ok() {
                return Err(format!("truncated frame (cut {cut}/{}) decoded", frame.len()));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_decoder_never_panics_on_bitflips() {
    // Arbitrary single-bit corruption anywhere in the frame: decoding may
    // succeed (a flipped payload bit) or fail with a structured error, but
    // must never panic or over-allocate its way to an abort.
    use rustures::ipc::wire::{decode_message, encode_message};
    let variant = std::cell::Cell::new(0usize);
    check("decoder-bitflips", 150, |g| {
        let msg = gen_message(g, variant.get());
        variant.set(variant.get() + 1);
        let frame = encode_message(&msg);
        for _ in 0..16 {
            let mut corrupt = frame.clone();
            let bit = g.usize_in(0, corrupt.len() * 8 - 1);
            corrupt[bit / 8] ^= 1 << (bit % 8);
            let _ = decode_message(&corrupt); // any Result is fine; no panic
        }
        Ok(())
    });
}

#[test]
fn prop_compressed_and_raw_frames_decode_identically() {
    // Compression is a transport detail: for every message, the compressed
    // and raw encodings decode to the same (original) message.
    use rustures::ipc::wire::{decode_message, encode_message_opts};
    let variant = std::cell::Cell::new(0usize);
    check("codec-identity", 120, |g| {
        let msg = gen_message(g, variant.get());
        variant.set(variant.get() + 1);
        let packed = decode_message(&encode_message_opts(&msg, true))
            .map_err(|e| format!("compressed decode: {e}"))?;
        let raw = decode_message(&encode_message_opts(&msg, false))
            .map_err(|e| format!("raw decode: {e}"))?;
        if packed != msg || raw != msg {
            return Err("compressed/raw decode disagreed with the original".into());
        }
        Ok(())
    });
}

#[test]
fn prop_relay_order_stdout_first_conditions_in_seq() {
    use rustures::api::conditions::{CaptureBuffer, ConditionKind};
    check("relay-order", 200, |g| {
        let mut buf = CaptureBuffer::new();
        let n = g.usize_in(0, 12);
        let mut expected_kinds = Vec::new();
        for _ in 0..n {
            match g.usize_in(0, 2) {
                0 => buf.capture_stdout("x"),
                1 => {
                    buf.signal(ConditionKind::Message, "m");
                    expected_kinds.push(ConditionKind::Message);
                }
                _ => {
                    buf.signal(ConditionKind::Warning, "w");
                    expected_kinds.push(ConditionKind::Warning);
                }
            }
        }
        let captured = buf.finish();
        let order = captured.relay_order(false);
        // Conditions relayed in capture order.
        let kinds: Vec<ConditionKind> = order.iter().map(|c| c.kind).collect();
        if kinds != expected_kinds {
            return Err(format!("order {kinds:?} != {expected_kinds:?}"));
        }
        let seqs: Vec<u64> = order.iter().map(|c| c.seq).collect();
        if seqs.windows(2).any(|w| w[0] >= w[1]) {
            return Err(format!("non-monotone seq {seqs:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_cache_key_is_chunking_invariant_and_pure() {
    use rustures::cache::{cache_key, chunk_element_keys};
    check("cache-key-chunking-invariant", 200, |g| {
        let param = g.ident();
        // `gen_sound_expr` never draws from the RNG; append a draw to half
        // the bodies so both keying regimes are exercised.
        let mut body = gen_sound_expr(g, 3);
        if g.bool() {
            body = Expr::seq(vec![body, Expr::runif(1)]);
        }
        let n = g.usize_in(1, 16);
        let elements: Vec<Value> = (0..n).map(|_| gen_value(g, 2)).collect();
        let seed = if g.bool() { Some(g.u64()) } else { None };
        let mut env = Env::new();
        for _ in 0..g.usize_in(0, 3) {
            env.insert(&g.ident(), gen_value(g, 1));
        }

        // Reference: one chunk covering every element from base index 0.
        let reference = chunk_element_keys(&param, &body, &elements, 0, seed, &env);

        // ANY partition of the same elements — each chunk keyed under its
        // global base index, the rule `future_lapply` uses — reproduces the
        // reference key stream element for element.  This is exactly why a
        // warm run under a different chunking policy hits every entry.
        let mut keys = Vec::with_capacity(n);
        let mut start = 0usize;
        while start < n {
            let len = g.usize_in(1, n - start);
            let chunk = &elements[start..start + len];
            keys.extend(chunk_element_keys(&param, &body, chunk, start as u64, seed, &env));
            start += len;
        }
        if keys != reference {
            return Err(format!("partitioned keys diverge for n={n}"));
        }

        // Keys are a pure function of their inputs (same call, same
        // digests) — no backend, session, or ambient state participates.
        if chunk_element_keys(&param, &body, &elements, 0, seed, &env) != reference {
            return Err("chunk keys are not deterministic".into());
        }
        let whole = cache_key(&body, &env, seed, 3);
        if cache_key(&body, &env, seed, 3) != whole {
            return Err("whole-future key is not deterministic".into());
        }

        // The stream index participates exactly when the body draws RNG:
        // deterministic work dedups across creation ordinals, seeded draws
        // stay distinct per substream.
        let shifted = cache_key(&body, &env, seed, 4);
        if body.uses_rng() {
            if shifted == whole {
                return Err("RNG body must key per stream index".into());
            }
        } else if shifted != whole {
            return Err("non-RNG body must ignore the stream index".into());
        }
        Ok(())
    });
}
