//! As-completed resolution end to end: queued (non-blocking) dispatch,
//! `resolve()`/`FutureSet` wake-ups over the shared completion channel, and
//! the streaming map-reduce equivalence guarantees — the acceptance gates
//! for the dispatcher subsystem.

use std::time::{Duration, Instant};

use rustures::api::plan::{with_plan, PlanSpec};
use rustures::prelude::*;
use rustures::proptest_lite::check;

fn xs(n: usize) -> Vec<Value> {
    (0..n as i64).map(Value::I64).collect()
}

#[test]
fn queued_creation_does_not_block_when_all_workers_busy() {
    // The tentpole behaviour: with FutureOpts::queued, future() enqueues on
    // the dispatcher backlog and returns immediately even though every
    // worker seat is taken — where the paper's default would block.
    for spec in [PlanSpec::multicore(1), PlanSpec::multiprocess(1)] {
        let name = spec.name();
        with_plan(spec, || {
            let env = Env::new();
            let slow = future(Expr::Spin { millis: 300 }, &env).unwrap();
            let t0 = Instant::now();
            let f = future_with(Expr::lit(5i64), &env, FutureOpts::new().queued()).unwrap();
            let create = t0.elapsed();
            assert!(
                create < Duration::from_millis(150),
                "{name}: queued create blocked for {create:?}"
            );
            assert!(!f.resolved(), "{name}: queued future cannot be resolved yet");
            assert_eq!(f.value().unwrap(), Value::I64(5), "{name}");
            slow.value().unwrap();
        });
    }
}

#[test]
fn blocking_create_default_is_preserved() {
    // The paper's semantic must survive the dispatcher: WITHOUT queued,
    // the third create on two busy workers still blocks.
    with_plan(PlanSpec::multicore(2), || {
        let env = Env::new();
        let _f1 = future(Expr::Spin { millis: 200 }, &env).unwrap();
        let _f2 = future(Expr::Spin { millis: 200 }, &env).unwrap();
        let t0 = Instant::now();
        let f3 = future(Expr::lit(3i64), &env).unwrap();
        assert!(
            t0.elapsed() >= Duration::from_millis(50),
            "default create should have blocked, took {:?}",
            t0.elapsed()
        );
        assert_eq!(f3.value().unwrap(), Value::I64(3));
    });
}

#[test]
fn resolve_any_wakes_on_the_fast_future() {
    // resolve_any must return as soon as the FAST racer resolves — long
    // before the slow one — woken by the shared completion channel.
    for spec in [PlanSpec::multicore(2), PlanSpec::multiprocess(2)] {
        let name = spec.name();
        with_plan(spec, || {
            let env = Env::new();
            let fs = vec![
                future(
                    Expr::seq(vec![Expr::Spin { millis: 600 }, Expr::lit("slow")]),
                    &env,
                )
                .unwrap(),
                future(
                    Expr::seq(vec![Expr::Spin { millis: 5 }, Expr::lit("fast")]),
                    &env,
                )
                .unwrap(),
            ];
            let t0 = Instant::now();
            let i = resolve_any(&fs).expect("non-empty");
            let waited = t0.elapsed();
            assert_eq!(i, 1, "{name}: fast future should win");
            assert!(
                waited < Duration::from_millis(450),
                "{name}: resolve_any waited {waited:?} — did it block on the slow future?"
            );
            assert_eq!(fs[1].value().unwrap(), Value::Str("fast".into()), "{name}");
            // The slow one still completes normally afterwards.
            assert_eq!(fs[0].value().unwrap(), Value::Str("slow".into()), "{name}");
        });
    }
}

#[test]
fn future_set_streams_completions_in_completion_order() {
    with_plan(PlanSpec::multicore(3), || {
        let env = Env::new();
        // One slow future (index 0) and two fast ones; three workers, so
        // all three run concurrently from creation.
        let delays = [300u64, 5, 10];
        let fs: Vec<Future> = delays
            .iter()
            .enumerate()
            .map(|(i, d)| {
                future(
                    Expr::seq(vec![Expr::Spin { millis: *d }, Expr::lit(i as i64)]),
                    &env,
                )
                .unwrap()
            })
            .collect();
        let mut set = FutureSet::new(&fs);
        let mut order = Vec::new();
        while let Some(i) = set.wait_any() {
            order.push(i);
        }
        let mut sorted = order.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2], "every index exactly once: {order:?}");
        // The slow future must be reported LAST — the as-completed property
        // (an in-order harvest would report 0 first after blocking on it).
        let pos = |x: usize| order.iter().position(|&i| i == x).unwrap();
        assert!(pos(1) < pos(0), "fast future reported after slow one: {order:?}");
        assert!(pos(2) < pos(0), "fast future reported after slow one: {order:?}");
    });
}

#[test]
fn streaming_lapply_bit_identical_across_backends_and_chunkings() {
    // The acceptance gate: seeded future_lapply output is bit-identical to
    // the pre-change (strictly in-order) collection under EVERY chunking
    // policy on sequential, multicore, multisession, and cluster.
    let xs = xs(9);
    let body = Expr::add(Expr::var("x"), Expr::runif(2));
    let reference = with_plan(PlanSpec::sequential(), || {
        future_lapply(
            &xs,
            "x",
            &body,
            &Env::new(),
            &LapplyOpts::new().seed(1234).in_order(),
        )
        .unwrap()
    });
    assert_eq!(reference.len(), xs.len());
    let policies = [
        ("per-element", Chunking::PerElement),
        ("chunk=4", Chunking::ChunkSize(4)),
        ("per-worker", Chunking::PerWorker),
        ("scheduling=2", Chunking::Scheduling(2.0)),
    ];
    for spec in [
        PlanSpec::sequential(),
        PlanSpec::multicore(2),
        PlanSpec::multiprocess(2),
        PlanSpec::cluster(&["n1.local", "n2.local"]),
    ] {
        for (label, chunking) in policies {
            let got = with_plan(spec.clone(), || {
                future_lapply(
                    &xs,
                    "x",
                    &body,
                    &Env::new(),
                    &LapplyOpts::new().seed(1234).chunking(chunking),
                )
                .unwrap()
            });
            assert_eq!(got, reference, "{}/{} diverged", spec.name(), label);
        }
    }
}

#[test]
fn prop_streaming_equals_in_order_collection() {
    // Property: for random n, seed, chunking (including the pathological
    // Scheduling factors and ChunkSize(0)) and worker count, as-completed
    // collection is bit-identical to the in-order reference.
    check("streaming-vs-in-order", 20, |g| {
        let n = g.usize_in(1, 12);
        let elems = xs(n);
        let seed = g.u64();
        let chunking = match g.usize_in(0, 4) {
            0 => Chunking::PerElement,
            1 => Chunking::PerWorker,
            2 => Chunking::Scheduling(g.f64_in(-1.0, 4.0)),
            3 => Chunking::ChunkSize(g.usize_in(0, 5)),
            _ => Chunking::Scheduling(f64::NAN),
        };
        let workers = g.usize_in(1, 3);
        let body = Expr::add(Expr::var("x"), Expr::runif(1));
        let (streamed, ordered) = with_plan(PlanSpec::multicore(workers), || {
            let env = Env::new();
            let opts = LapplyOpts::new().seed(seed).chunking(chunking);
            let streamed = future_lapply(&elems, "x", &body, &env, &opts)
                .map_err(|e| e.to_string());
            let ordered = future_lapply(&elems, "x", &body, &env, &opts.clone().in_order())
                .map_err(|e| e.to_string());
            (streamed, ordered)
        });
        let (streamed, ordered) = (streamed?, ordered?);
        if streamed != ordered {
            return Err(format!(
                "mismatch: n={n} workers={workers} chunking={chunking:?}"
            ));
        }
        Ok(())
    });
}

#[test]
fn map_reduce_folds_skewed_chunks_as_they_complete() {
    // Skewed workload: element 0 spins, so its chunk resolves LAST; the
    // completion-order fold must still produce the exact commutative total.
    let n = 8usize;
    let body = Expr::if_else(
        Expr::prim(PrimOp::Eq, vec![Expr::var("x"), Expr::lit(0i64)]),
        Expr::seq(vec![
            Expr::Spin { millis: 80 },
            Expr::mul(Expr::var("x"), Expr::var("x")),
        ]),
        Expr::mul(Expr::var("x"), Expr::var("x")),
    );
    let want: i64 = (0..n as i64).map(|i| i * i).sum();
    for spec in [PlanSpec::sequential(), PlanSpec::multicore(2), PlanSpec::multiprocess(2)] {
        let name = spec.name();
        let total = with_plan(spec, || {
            future_map_reduce(
                &xs(n),
                "x",
                &body,
                &Env::new(),
                &LapplyOpts::new().chunking(Chunking::ChunkSize(2)),
                Value::I64(0),
                |acc, v| match (acc, v) {
                    (Value::I64(a), Value::I64(b)) => Ok(Value::I64(a + b)),
                    other => panic!("unexpected fold inputs: {other:?}"),
                },
            )
            .unwrap()
        });
        assert_eq!(total, Value::I64(want), "{name}");
    }
}

#[test]
fn queued_lapply_is_bit_identical_on_parallel_backends() {
    let elems = xs(8);
    let body = Expr::add(Expr::var("x"), Expr::runif(1));
    let reference = with_plan(PlanSpec::sequential(), || {
        future_lapply(&elems, "x", &body, &Env::new(), &LapplyOpts::new().seed(77)).unwrap()
    });
    for spec in [PlanSpec::multicore(2), PlanSpec::multiprocess(2)] {
        let got = with_plan(spec.clone(), || {
            future_lapply(
                &elems,
                "x",
                &body,
                &Env::new(),
                &LapplyOpts::new().seed(77).queued().chunking(Chunking::ChunkSize(3)),
            )
            .unwrap()
        });
        assert_eq!(got, reference, "{} queued diverged", spec.name());
    }
}

#[test]
fn tweaked_grown_cluster_actually_runs() {
    // tweak_workers growth used to silently no-op for Cluster; the grown
    // plan must really spawn the extra simulated host.
    let spec = PlanSpec::cluster(&["n1.local"]).tweak_workers(2);
    assert_eq!(spec.effective_workers(), 2);
    with_plan(spec, || {
        let env = Env::new();
        let out = future_lapply(
            &xs(6),
            "x",
            &Expr::mul(Expr::var("x"), Expr::lit(3i64)),
            &env,
            &LapplyOpts::new(),
        )
        .unwrap();
        assert_eq!(out, (0..6i64).map(|i| Value::I64(i * 3)).collect::<Vec<_>>());
    });
}

#[test]
fn resolve_works_on_batch_futures_without_polling_handles() {
    // The scheduler's daemon push-notifies terminal transitions; resolve()
    // over batch futures must terminate and leave every value collectable.
    with_plan(PlanSpec::batch(2), || {
        let env = Env::new();
        let fs: Vec<Future> = (0..3)
            .map(|i| future(Expr::lit(i as i64), &env).unwrap())
            .collect();
        resolve(&fs);
        for (i, f) in fs.iter().enumerate() {
            assert!(f.resolved());
            assert_eq!(f.value().unwrap(), Value::I64(i as i64));
        }
    });
}
