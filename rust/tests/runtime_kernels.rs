//! Integration: AOT artifacts execute correctly through PJRT, from the
//! coordinator and from worker processes (the full L1→L2→L3 composition).
//!
//! Requires `make artifacts` (skipped gracefully otherwise).

use rustures::api::plan::{with_plan, PlanSpec};
use rustures::prelude::*;

fn runtime() -> Option<rustures::runtime::RuntimeHandle> {
    rustures::runtime::global().map(|rt| rt.handle())
}

fn uniform_tensor(shape: &[usize], seed: u64) -> Tensor {
    let mut rng = RngStream::from_seed(seed);
    let n: usize = shape.iter().product();
    Tensor::new(shape.to_vec(), rng.unif_f32(n)).unwrap()
}

#[test]
fn slow_fcn_direct_execution_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let x = Value::Tensor(uniform_tensor(&[128, 128], 1));
    let a = rt.execute("slow_fcn", vec![x.clone()]).unwrap();
    let b = rt.execute("slow_fcn", vec![x]).unwrap();
    assert_eq!(a, b);
    let t = a.as_tensor().unwrap();
    assert_eq!(t.shape, vec![128, 128]);
    assert!(t.data.iter().all(|v| v.is_finite() && v.abs() <= 1.0)); // tanh-bounded
}

#[test]
fn kernel_arg_validation_errors_cleanly() {
    let Some(rt) = runtime() else { return };
    // Wrong arity.
    let err = rt.execute("slow_fcn", vec![]).unwrap_err();
    assert!(err.message.contains("expected 1 arguments"));
    // Wrong shape.
    let bad = Value::Tensor(Tensor::zeros(&[2, 2]));
    let err = rt.execute("slow_fcn", vec![bad]).unwrap_err();
    assert!(err.message.contains("shape"));
    // Unknown kernel.
    let err = rt.execute("nope", vec![]).unwrap_err();
    assert!(err.message.contains("could not find function"));
    // Non-tensor argument.
    let err = rt.execute("slow_fcn", vec![Value::I64(1)]).unwrap_err();
    assert!(err.message.contains("must be a tensor"));
}

#[test]
fn bootstrap_stat_recovers_known_line() {
    let Some(rt) = runtime() else { return };
    // y = 3x - 1 exactly: WLS must return slope 3, intercept -1.
    let n = 4096;
    let mut rng = RngStream::from_seed(7);
    let mut data = Vec::with_capacity(n * 2);
    for _ in 0..n {
        let x = rng.next_unif() as f32 * 4.0 - 2.0;
        data.push(x);
        data.push(3.0 * x - 1.0);
    }
    let xy = Value::Tensor(Tensor::new(vec![n, 2], data).unwrap());
    let w = Value::Tensor(Tensor::new(vec![n], vec![1.0; n]).unwrap());
    let out = rt.execute("bootstrap_stat", vec![xy, w]).unwrap();
    let parts = out.as_list().unwrap();
    let slope = parts[0].as_f64().unwrap();
    let intercept = parts[1].as_f64().unwrap();
    assert!((slope - 3.0).abs() < 1e-2, "slope {slope}");
    assert!((intercept + 1.0).abs() < 1e-2, "intercept {intercept}");
}

#[test]
fn mc_pi_block_estimates_pi() {
    let Some(rt) = runtime() else { return };
    let u = Value::Tensor(uniform_tensor(&[8192, 2], 99));
    let out = rt.execute("mc_pi_block", vec![u]).unwrap();
    let pi = out.as_f64().unwrap();
    assert!((pi - std::f64::consts::PI).abs() < 0.1, "pi estimate {pi}");
}

#[test]
fn mlp_step_reduces_loss_over_iterations() {
    let Some(rt) = runtime() else { return };
    let d = 128;
    let mut rng = RngStream::from_seed(3);
    let scale = 0.1f32;
    let mk = |rng: &mut RngStream, shape: &[usize], s: f32| {
        let n: usize = shape.iter().product();
        let data: Vec<f32> = rng.norm_f32(n).iter().map(|v| v * s).collect();
        Value::Tensor(Tensor::new(shape.to_vec(), data).unwrap())
    };
    let mut w1 = mk(&mut rng, &[d, d], scale);
    let mut b1 = Value::Tensor(Tensor::zeros(&[d]));
    let mut w2 = mk(&mut rng, &[d, d], scale);
    let mut b2 = Value::Tensor(Tensor::zeros(&[d]));
    let x = mk(&mut rng, &[d, d], 1.0);
    let y = mk(&mut rng, &[d, d], 0.5);

    let mut losses = Vec::new();
    for _ in 0..4 {
        let out = rt
            .execute("mlp_step", vec![w1, b1, w2, b2, x.clone(), y.clone()])
            .unwrap();
        let parts = out.as_list().unwrap().to_vec();
        losses.push(parts[0].as_f64().unwrap());
        w1 = parts[1].clone();
        b1 = parts[2].clone();
        w2 = parts[3].clone();
        b2 = parts[4].clone();
    }
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease: {losses:?}"
    );
}

#[test]
fn kernel_call_through_future_on_worker_process() {
    // The full stack: future → multisession worker process → PJRT → result.
    with_plan(PlanSpec::multiprocess(1), || {
        let mut env = Env::new();
        env.insert("x", Value::Tensor(uniform_tensor(&[128, 128], 5)));
        let f = future(Expr::call("slow_fcn", vec![Expr::var("x")]), &env).unwrap();
        match f.value() {
            Ok(v) => {
                let t = v.as_tensor().unwrap();
                assert_eq!(t.shape, vec![128, 128]);
                // Must equal direct (coordinator-side) execution.
                if let Some(rt) = runtime() {
                    let direct = rt
                        .execute(
                            "slow_fcn",
                            vec![Value::Tensor(uniform_tensor(&[128, 128], 5))],
                        )
                        .unwrap();
                    assert_eq!(v, direct);
                }
            }
            // Artifacts absent in the workers: the future must fail with a
            // clean eval error, not hang.
            Err(FutureError::Eval(e)) => {
                assert!(e.message.contains("slow_fcn"));
            }
            Err(other) => panic!("unexpected failure: {other}"),
        }
    });
}

#[test]
fn same_kernel_result_on_every_backend() {
    let Some(_) = runtime() else { return };
    let x = Value::Tensor(uniform_tensor(&[128, 128], 11));
    let run = |spec: PlanSpec| {
        with_plan(spec, || {
            let mut env = Env::new();
            env.insert("x", x.clone());
            future(Expr::call("slow_fcn", vec![Expr::var("x")]), &env)
                .unwrap()
                .value()
                .unwrap()
        })
    };
    let seq = run(PlanSpec::sequential());
    let thr = run(PlanSpec::multicore(2));
    let proc = run(PlanSpec::multiprocess(1));
    assert_eq!(seq, thr, "sequential vs multicore");
    assert_eq!(seq, proc, "sequential vs multisession");
}
