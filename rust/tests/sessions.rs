//! Session-first API integration tests: multiple concurrent `Session`s in
//! one process (different backends, bit-identical seeded results, isolated
//! supervision counters, unique ids), serialized context propagation to
//! workers (the PR 3 nested-retry gap), and closed-session semantics.

use std::time::Duration;

use rustures::api::plan::{current_plan_retry, current_topology};
use rustures::api::session::scope_task_context;
use rustures::ipc::wire::{decode_message, encode_message};
use rustures::ipc::{Message, TaskOpts, TaskSpec};
use rustures::mapreduce::Chunking;
use rustures::prelude::*;

fn xs(n: i64) -> Vec<Value> {
    (0..n).map(Value::I64).collect()
}

fn seeded_opts(seed: u64) -> LapplyOpts {
    LapplyOpts::new().seed(seed).chunking(Chunking::ChunkSize(2))
}

#[test]
fn two_concurrent_sessions_on_different_backends_are_bit_identical() {
    let env = Env::new();
    let body = Expr::add(Expr::var("x"), Expr::runif(1));

    // Reference under a fresh sequential session.
    let reference = Session::with_plan(PlanSpec::sequential());
    let want = reference.lapply(&xs(8), "x", &body, &env, &seeded_opts(23)).unwrap();
    reference.close();

    let a = Session::with_plan(PlanSpec::multicore(2));
    let b = Session::with_plan(PlanSpec::multiprocess(2));

    // Interleave heavily: both sessions map concurrently, twice each.
    let ea = Env::new();
    let eb = Env::new();
    std::thread::scope(|s| {
        let ta = s.spawn(|| {
            let r1 = a.lapply(&xs(8), "x", &body, &ea, &seeded_opts(23)).unwrap();
            let r2 = a.lapply(&xs(8), "x", &body, &ea, &seeded_opts(23)).unwrap();
            (r1, r2)
        });
        let tb = s.spawn(|| {
            let r1 = b.lapply(&xs(8), "x", &body, &eb, &seeded_opts(23)).unwrap();
            let r2 = b.lapply(&xs(8), "x", &body, &eb, &seeded_opts(23)).unwrap();
            (r1, r2)
        });
        let (a1, a2) = ta.join().unwrap();
        let (b1, b2) = tb.join().unwrap();
        assert_eq!(a1, want, "session A run 1");
        assert_eq!(a2, want, "session A run 2 (per-session counters: no drift)");
        assert_eq!(b1, want, "session B run 1");
        assert_eq!(b2, want, "session B run 2");
    });

    a.close();
    b.close();
}

#[test]
fn future_ids_are_unique_and_prefixed_across_sessions() {
    let a = Session::with_plan(PlanSpec::sequential());
    let b = Session::with_plan(PlanSpec::sequential());
    let env = Env::new();
    let mut ids = std::collections::HashSet::new();
    for _ in 0..10 {
        let fa = a.future(Expr::lit(1i64), &env).unwrap();
        let fb = b.future(Expr::lit(2i64), &env).unwrap();
        assert!(fa.id().starts_with(&format!("s{}-", a.id())));
        assert!(fb.id().starts_with(&format!("s{}-", b.id())));
        assert_eq!(fa.session_id(), a.id());
        assert!(ids.insert(fa.id().to_string()), "duplicate id {}", fa.id());
        assert!(ids.insert(fb.id().to_string()), "duplicate id {}", fb.id());
    }
    a.close();
    b.close();
}

#[test]
fn session_counters_reset_independently() {
    // reset_session_counter() (free function) targets the scoped session
    // only: session B's stream assignment is unaffected by A's resets.
    let a = Session::with_plan(PlanSpec::sequential());
    let b = Session::with_plan(PlanSpec::sequential());
    let env = Env::new();

    let draw = |s: &Session| {
        s.future_with(Expr::rnorm(2), &env, FutureOpts::new().seed(5))
            .unwrap()
            .value()
            .unwrap()
    };
    let b0 = draw(&b); // B stream 0
    let _ = draw(&a); // A stream 0
    a.scope(|_| rustures::api::future::reset_session_counter());
    let a0 = draw(&a); // A stream 0 again (reset)
    let b1 = draw(&b); // B stream 1 — unaffected by A's reset
    assert_ne!(b0, b1, "B advanced to its next stream");
    let fresh = Session::with_plan(PlanSpec::sequential());
    assert_eq!(draw(&fresh), b0, "stream 0 is deterministic across sessions");
    assert_eq!(a0, b0, "A's reset re-yields stream 0");
    a.close();
    b.close();
    fresh.close();
}

#[test]
fn dropped_session_latches_clear_error_on_unresolvable_futures() {
    let s = Session::with_plan(PlanSpec::multicore(1));
    let env = Env::new();
    // Never launched: can never complete once the session closes.
    let lazy = s
        .future_with(Expr::lit(5i64), &env, FutureOpts::new().lazy())
        .unwrap();
    // Launched and finished by the worker, but never collected: close()
    // must NOT discard a result the backend already produced.
    let computed = s.future(Expr::lit(9i64), &env).unwrap();
    // Fully collected before the close: trivially survives.
    let done = s.future(Expr::lit(7i64), &env).unwrap();
    assert_eq!(done.value().unwrap(), Value::I64(7));
    s.close();

    match lazy.value() {
        Err(FutureError::SessionClosed { session }) => assert_eq!(session, s.id()),
        other => panic!("expected SessionClosed, got {other:?}"),
    }
    // Latched: probes and repeat collections agree forever after.
    assert!(lazy.resolved());
    assert!(matches!(lazy.value(), Err(FutureError::SessionClosed { .. })));
    // The worker-computed result was parked before the close and survives.
    assert_eq!(computed.value().unwrap(), Value::I64(9));
    assert_eq!(done.value().unwrap(), Value::I64(7));
    // And new futures are rejected outright.
    assert!(matches!(
        s.future(Expr::lit(1i64), &env),
        Err(FutureError::SessionClosed { .. })
    ));
}

#[test]
fn nested_retry_default_reaches_workers_via_wire_context() {
    // Regression for the PR 3 gap: plan-level RetryPolicy used to be
    // session-local — a worker's nested plan had no retry default.  The
    // serialized SessionContext (protocol v4) now carries it; this test
    // walks the exact worker path: encode → decode → install.
    let retry = RetryPolicy::idempotent(4);
    let s = Session::new();
    s.plan_topology_with_retry(
        vec![PlanSpec::multiprocess(2), PlanSpec::multicore(2)],
        Some(retry.clone()),
    );

    let ctx = s.context_for_depth(0);
    assert_eq!(ctx.session, s.id());
    assert_eq!(ctx.retry, Some(retry.clone()));
    assert_eq!(ctx.nested_plan, vec![PlanSpec::multicore(2)]);

    let task = TaskSpec {
        id: "probe".into(),
        expr: Expr::lit(0i64),
        globals: Env::new(),
        opts: TaskOpts { context: ctx, ..TaskOpts::default() },
    };
    let decoded = match decode_message(&encode_message(&Message::Task(task))).unwrap() {
        Message::Task(t) => t,
        other => panic!("expected task, got {other:?}"),
    };
    let (worker_retry, worker_topology, worker_session_of_nested) =
        scope_task_context(&decoded.opts.context, || {
            let env = Env::new();
            // A nested future created "on the worker" — its own shipped
            // context must keep inheriting the retry default (depth 1+).
            let f = future(Expr::lit(3i64), &env).unwrap();
            let v = f.value().unwrap();
            assert_eq!(v, Value::I64(3));
            (current_plan_retry(), current_topology(), f.session_id())
        });
    assert_eq!(worker_retry, Some(retry), "nested plan must inherit the retry default");
    assert_eq!(worker_topology, vec![PlanSpec::multicore(2)]);
    // Worker-side metrics attribute to the ORIGIN session id.
    let _ = worker_session_of_nested;
    s.close();
}

#[test]
fn nested_chunks_run_on_real_workers_with_context() {
    // End to end through worker processes: a two-level topology ships its
    // tail in every task; the map completes and the coordinator session's
    // own state is untouched by worker-side context installs.
    let s = Session::with_topology(vec![PlanSpec::multiprocess(2), PlanSpec::Sequential]);
    let env = Env::new();
    let out = s
        .lapply(
            &xs(6),
            "x",
            &Expr::mul(Expr::var("x"), Expr::lit(3i64)),
            &env,
            &LapplyOpts::new(),
        )
        .unwrap();
    assert_eq!(out, (0..6).map(|i| Value::I64(i * 3)).collect::<Vec<_>>());
    assert_eq!(
        s.topology(),
        vec![PlanSpec::multiprocess(2), PlanSpec::Sequential],
        "coordinator topology unchanged"
    );
    s.close();
}

#[test]
fn sessions_do_not_share_dispatchers_or_queues() {
    // Queued dispatch in one session must not interfere with another
    // session's futures: fill A's single seat and backlog, then B's
    // futures still resolve promptly.
    let a = Session::with_plan(PlanSpec::multicore(1));
    let b = Session::with_plan(PlanSpec::multicore(1));
    let env = Env::new();
    let _slow = a.future(Expr::Sleep { millis: 120 }, &env).unwrap();
    let queued: Vec<_> = (0..3)
        .map(|i| {
            a.future_with(Expr::lit(i as i64), &env, FutureOpts::new().queued()).unwrap()
        })
        .collect();
    let t0 = std::time::Instant::now();
    let vb = b.future(Expr::lit(77i64), &env).unwrap().value().unwrap();
    assert_eq!(vb, Value::I64(77));
    assert!(
        t0.elapsed() < Duration::from_millis(100),
        "session B stalled behind A's queue: {:?}",
        t0.elapsed()
    );
    for (i, f) in queued.iter().enumerate() {
        assert_eq!(f.value().unwrap(), Value::I64(i as i64));
    }
    a.close();
    b.close();
}

#[test]
fn supervision_counters_keyed_per_session_in_json() {
    let a = Session::with_plan(PlanSpec::multicore(1));
    let env = Env::new();
    let before = a.supervision_counters();
    let f = a.future(Expr::chaos_kill(), &env).unwrap();
    assert!(matches!(f.value(), Err(e) if !e.is_eval()));
    let after = a.supervision_counters();
    assert!(
        after.worker_deaths >= before.worker_deaths + 1,
        "kill must be attributed to the owning session: {before:?} -> {after:?}"
    );

    // And the JSON schema surfaces the per-session entry.
    let json = rustures::metrics::supervision_json();
    assert!(json.contains("\"schema\":\"rustures.supervision.v1\""));
    assert!(
        json.contains(&format!("\"session\":{}", a.id())),
        "supervision_json missing session {}: {json}",
        a.id()
    );
    a.close();
}
