//! Supervisor kill-tests: worker respawn across backends, the cluster
//! accept-timeout bugfix, respawn budgets, and supervision metrics — the
//! elastic-execution half of the fault-tolerance subsystem (the retry half
//! lives in tests/failure_injection.rs).

use std::time::{Duration, Instant};

use rustures::api::plan::{with_plan, PlanSpec};
use rustures::backend::cluster::ClusterBackend;
use rustures::backend::{Backend, TaskHandle};
use rustures::prelude::*;

fn marker(tag: &str) -> String {
    std::env::temp_dir()
        .join(format!("rustures-sup-{tag}-{}", rustures::util::uuid_v4()))
        .to_string_lossy()
        .into_owned()
}

/// Kill a worker (no retry → the future fails), then verify the backend
/// still serves: the health monitor / on-demand respawn restored capacity.
fn assert_kill_then_respawn(spec: PlanSpec) {
    with_plan(spec.clone(), || {
        let env = Env::new();
        let f = future(Expr::chaos_kill(), &env).unwrap();
        match f.value() {
            Err(e) => {
                assert!(!e.is_eval(), "{}: kill must not be an eval error: {e}", spec.name());
                assert!(e.is_recoverable(), "{}: {e}", spec.name());
            }
            Ok(v) => panic!("{}: killed future returned {v:?}", spec.name()),
        }
        // Fresh capacity: a whole map still runs to completion.
        let xs: Vec<Value> = (0..8i64).map(Value::I64).collect();
        let out = future_lapply(
            &xs,
            "x",
            &Expr::mul(Expr::var("x"), Expr::var("x")),
            &env,
            &LapplyOpts::new(),
        )
        .unwrap();
        let want: Vec<Value> = (0..8i64).map(|i| Value::I64(i * i)).collect();
        assert_eq!(out, want, "{}: pool did not recover", spec.name());
    });
}

#[test]
fn threadpool_respawns_after_kill() {
    assert_kill_then_respawn(PlanSpec::multicore(2));
}

#[test]
fn multisession_respawns_after_kill() {
    assert_kill_then_respawn(PlanSpec::multiprocess(2));
}

#[test]
fn cluster_respawns_after_kill() {
    assert_kill_then_respawn(PlanSpec::cluster(&["n1.local", "n2.local"]));
}

#[test]
fn batch_jobs_are_inherently_disposable() {
    // Each batch job is its own process: a killed job fails structurally
    // and the next job simply runs on a fresh process.
    assert_kill_then_respawn(PlanSpec::batch(2));
}

#[test]
fn killing_every_worker_still_recovers() {
    // Lose ALL workers at once; the monitor must rebuild the whole pool.
    with_plan(PlanSpec::multicore(2), || {
        let env = Env::new();
        let fs: Vec<Future> =
            (0..2).map(|_| future(Expr::chaos_kill(), &env).unwrap()).collect();
        for f in &fs {
            assert!(f.value().is_err());
        }
        let f = future(Expr::lit(42i64), &env).unwrap();
        assert_eq!(f.value().unwrap(), Value::I64(42));
    });
}

#[test]
fn respawn_counters_tick() {
    let before = rustures::metrics::supervision_counters();
    with_plan(PlanSpec::multicore(1), || {
        let env = Env::new();
        let f = future(Expr::chaos_kill(), &env).unwrap();
        assert!(f.value().is_err());
        // Force the respawned worker into service so the monitor must have
        // acted before this returns.
        let f = future(Expr::lit(1i64), &env).unwrap();
        assert_eq!(f.value().unwrap(), Value::I64(1));
    });
    let after = rustures::metrics::supervision_counters();
    assert!(after.worker_deaths > before.worker_deaths, "death not counted");
    assert!(after.respawns > before.respawns, "respawn not counted");
}

// ------------------------------------------------ cluster accept timeout ----

#[test]
fn cluster_accept_timeout_fails_fast_instead_of_hanging() {
    // Regression: launch_host_worker used to call accept() with no
    // deadline — a worker that spawns but never connects back hung plan
    // creation forever.  The "!noconnect" host label spawns exactly such a
    // worker; creation must give up within the deadline and kill the child.
    let t0 = Instant::now();
    let got = ClusterBackend::new_with_accept_timeout(
        &["sim1.local!noconnect".to_string()],
        Duration::from_millis(300),
    );
    let elapsed = t0.elapsed();
    match got {
        Err(FutureError::Launch(msg)) => {
            assert!(msg.contains("did not connect back"), "{msg}");
        }
        Err(other) => panic!("expected Launch error, got {other}"),
        Ok(_) => panic!("backend creation must fail when the worker never connects"),
    }
    assert!(
        elapsed < Duration::from_secs(5),
        "accept timeout did not bound plan creation: {elapsed:?}"
    );
}

#[test]
fn cluster_accept_timeout_does_not_affect_healthy_workers() {
    let backend = ClusterBackend::new_with_accept_timeout(
        &["n1.local".to_string()],
        Duration::from_secs(10),
    )
    .expect("healthy cluster");
    let mut h = backend
        .launch(rustures::ipc::TaskSpec {
            id: rustures::util::uuid_v4(),
            expr: Expr::add(Expr::lit(20i64), Expr::lit(22i64)),
            globals: Env::new(),
            opts: rustures::ipc::TaskOpts::default(),
        })
        .unwrap();
    let r = h.wait().unwrap();
    assert_eq!(r.outcome, rustures::ipc::TaskOutcome::Ok(Value::I64(42)));
    backend.shutdown();
}

// ---------------------------------------------------- retry determinism ----

#[test]
fn queued_dispatch_composes_with_retry() {
    // Queued (non-blocking-create) chunk futures still get supervision:
    // the dispatcher acquires the seat, the kill fires, the retry re-enters
    // the dispatcher, and the map completes bit-identically.
    let clean = with_plan(PlanSpec::multicore(2), || {
        let env = Env::new();
        let xs: Vec<Value> = (0..10i64).map(Value::I64).collect();
        let body = Expr::add(Expr::var("x"), Expr::runif(1));
        future_lapply(
            &xs,
            "x",
            &body,
            &env,
            &LapplyOpts::new().seed(7).chunking(Chunking::ChunkSize(2)).queued(),
        )
        .unwrap()
    });
    let m = marker("queued");
    let killed = with_plan(PlanSpec::multicore(2), || {
        let env = Env::new();
        let xs: Vec<Value> = (0..10i64).map(Value::I64).collect();
        let body = Expr::seq(vec![
            Expr::if_else(
                Expr::prim(PrimOp::Eq, vec![Expr::var("x"), Expr::lit(5i64)]),
                Expr::chaos_kill_once(&m),
                Expr::lit(0i64),
            ),
            Expr::add(Expr::var("x"), Expr::runif(1)),
        ]);
        future_lapply(
            &xs,
            "x",
            &body,
            &env,
            &LapplyOpts::new()
                .seed(7)
                .chunking(Chunking::ChunkSize(2))
                .queued()
                .retry(RetryPolicy::idempotent(4).with_backoff(Duration::from_millis(1), 2.0)),
        )
        .unwrap()
    });
    let _ = std::fs::remove_file(&m);
    // The clean body is `seq(lit, add)` vs `add` — same single draw per
    // element, so the values must match exactly.
    assert_eq!(killed, clean);
}

#[test]
fn map_reduce_survives_a_kill_with_retry() {
    let m = marker("mr");
    let total = with_plan(PlanSpec::multicore(2), || {
        let env = Env::new();
        let xs: Vec<Value> = (0..10i64).map(Value::I64).collect();
        let body = Expr::seq(vec![
            Expr::if_else(
                Expr::prim(PrimOp::Eq, vec![Expr::var("x"), Expr::lit(3i64)]),
                Expr::chaos_kill_once(&m),
                Expr::lit(0i64),
            ),
            Expr::mul(Expr::var("x"), Expr::var("x")),
        ]);
        future_map_reduce(
            &xs,
            "x",
            &body,
            &env,
            &LapplyOpts::new()
                .chunking(Chunking::ChunkSize(3))
                .retry(RetryPolicy::idempotent(4).with_backoff(Duration::from_millis(1), 2.0)),
            Value::I64(0),
            |acc, v| match (acc, v) {
                (Value::I64(a), Value::I64(b)) => Ok(Value::I64(a + b)),
                other => panic!("unexpected fold inputs: {other:?}"),
            },
        )
        .unwrap()
    });
    let _ = std::fs::remove_file(&m);
    let want: i64 = (0..10).map(|i| i * i).sum();
    assert_eq!(total, Value::I64(want));
}

#[test]
fn restart_still_works_for_supervised_futures() {
    // restart() (the manual recovery path) composes with supervision.
    with_plan(PlanSpec::multiprocess(1), || {
        let mut env = Env::new();
        env.insert("x", 21i64);
        let f = future_with(
            Expr::mul(Expr::var("x"), Expr::lit(2i64)),
            &env,
            FutureOpts::new().restartable().retry(RetryPolicy::idempotent(2)),
        )
        .unwrap();
        f.cancel();
        assert!(f.value().is_err(), "cancelled run fails (cancel disarms retry)");
        f.restart().unwrap();
        assert_eq!(f.value().unwrap(), Value::I64(42));
    });
}
