//! Transport-core integration: promise pipelining end-to-end (Forward
//! frames and the prebind fallback), dependency-failure propagation, the
//! reactor thread-shape guarantee, and an ignored 256-channel soak that
//! asserts one poll thread multiplexes every registered channel.

use std::time::{Duration, Instant};

use rustures::api::plan::with_plan;
use rustures::prelude::*;

/// `f2 = future(g(f1))` where `f1` is still in flight: the dependency's
/// value reaches the consumer's worker as a Forward frame (one hop), and
/// the consumer resolves to the composed result.
#[test]
fn pipelined_future_forwards_unresolved_dependency() {
    with_plan(PlanSpec::multiprocess(2), || {
        let env = Env::new();
        // Slow enough that f2 is created while f1 is still executing.
        let f1 = future(
            Expr::seq(vec![Expr::Sleep { millis: 120 }, Expr::lit(21i64)]),
            &env,
        )
        .unwrap();
        let dep_id = f1.id().to_string();
        let f2 = future_pipelined(
            Expr::add(Expr::await_future(&dep_id), Expr::lit(21i64)),
            &env,
            FutureOpts::new(),
            vec![f1],
        )
        .unwrap();
        assert_eq!(f2.value().unwrap(), Value::I64(42));
    });
}

/// A dependency that already resolved at creation time takes the prebind
/// path (its outcome ships inside the consumer's globals) and composes to
/// the same result as the forwarded path.
#[test]
fn pipelined_future_prebinds_resolved_dependency() {
    with_plan(PlanSpec::multiprocess(2), || {
        let env = Env::new();
        let f1 = future(Expr::lit(40i64), &env).unwrap();
        let give_up = Instant::now() + Duration::from_secs(10);
        while !f1.resolved() {
            assert!(Instant::now() < give_up, "dependency never resolved");
            std::thread::sleep(Duration::from_millis(2));
        }
        let dep_id = f1.id().to_string();
        let f2 = future_pipelined(
            Expr::add(Expr::await_future(&dep_id), Expr::lit(2i64)),
            &env,
            FutureOpts::new(),
            vec![f1],
        )
        .unwrap();
        assert_eq!(f2.value().unwrap(), Value::I64(42));
    });
}

/// Backends without channel transports (sequential) fall back to prebind —
/// pipelining is an optimization, never a requirement.
#[test]
fn pipelined_future_works_on_sequential_backend() {
    with_plan(PlanSpec::sequential(), || {
        let env = Env::new();
        let f1 = future(Expr::lit(20i64), &env).unwrap();
        let dep_id = f1.id().to_string();
        let f2 = future_pipelined(
            Expr::add(Expr::await_future(&dep_id), Expr::lit(22i64)),
            &env,
            FutureOpts::new(),
            vec![f1],
        )
        .unwrap();
        assert_eq!(f2.value().unwrap(), Value::I64(42));
    });
}

/// A failed dependency surfaces on the consumer as an evaluation error
/// carrying the original message — never a hang, never a silent default.
#[test]
fn pipelined_dependency_error_propagates_to_consumer() {
    with_plan(PlanSpec::multiprocess(2), || {
        let env = Env::new();
        let f1 = future(
            Expr::seq(vec![
                Expr::Sleep { millis: 80 },
                Expr::stop(Expr::lit("boom")),
            ]),
            &env,
        )
        .unwrap();
        let dep_id = f1.id().to_string();
        let f2 = future_pipelined(
            Expr::add(Expr::await_future(&dep_id), Expr::lit(1i64)),
            &env,
            FutureOpts::new(),
            vec![f1],
        )
        .unwrap();
        match f2.value() {
            Err(e) => {
                let msg = e.to_string();
                assert!(msg.contains("boom"), "original error text lost: {msg}");
            }
            Ok(v) => panic!("failed dependency produced a value: {v:?}"),
        }
    });
}

/// After a multiprocess run the process holds exactly one reactor thread
/// and zero legacy per-seat reader threads (Linux probe; skipped where
/// /proc is unavailable).
#[test]
fn multiprocess_run_leaves_one_reactor_zero_readers() {
    with_plan(PlanSpec::multiprocess(3), || {
        let env = Env::new();
        let xs: Vec<Value> = (0..9i64).map(Value::I64).collect();
        let body = Expr::add(Expr::var("x"), Expr::runif(1));
        let got = future_lapply(
            &xs,
            "x",
            &body,
            &env,
            &LapplyOpts::new().seed(13).chunking(Chunking::ChunkSize(3)),
        )
        .unwrap();
        assert_eq!(got.len(), xs.len());
        if let Some(tc) = rustures::transport::thread_counts() {
            assert_eq!(
                tc.readers, 0,
                "per-seat reader threads must not exist: {tc:?}"
            );
            assert_eq!(
                tc.reactor, 1,
                "exactly one poll thread must serve all seats: {tc:?}"
            );
        }
    });
}

/// Soak: 256 simulated worker channels (socketpairs) registered with the
/// transport at once — every inbound frame demultiplexed, every outbound
/// write drained, all by ONE reactor thread.  Ignored by default (fd- and
/// wall-clock-heavy); CI runs it in the transport soak step via
/// `cargo test --test transport -- --ignored`.
#[cfg(unix)]
#[test]
#[ignore]
fn soak_256_channels_single_reactor_thread() {
    use std::os::unix::io::AsRawFd;
    use std::os::unix::net::UnixStream;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::sync::Arc;

    use rustures::ipc::frame::write_message;
    use rustures::ipc::Message;
    use rustures::transport::{self, ChannelEvent, Endpoint};

    const N: usize = 256;

    let frames = Arc::new(AtomicUsize::new(0));
    let closed = Arc::new(AtomicUsize::new(0));
    let mut peers = Vec::with_capacity(N);
    let mut channels = Vec::with_capacity(N);

    for i in 0..N {
        let (ours, theirs) = UnixStream::pair().expect("socketpair");
        let reader = ours.try_clone().expect("dup");
        let (rfd, wfd) = (reader.as_raw_fd(), ours.as_raw_fd());
        let frames = Arc::clone(&frames);
        let closed = Arc::clone(&closed);
        let ch = transport::register(
            &format!("soak-{i}"),
            Endpoint::with_fds(Box::new(reader), Box::new(ours), rfd, wfd),
            Arc::new(move |ev| match ev {
                ChannelEvent::Message(_) => {
                    frames.fetch_add(1, Ordering::SeqCst);
                }
                ChannelEvent::Closed | ChannelEvent::Error(_) => {
                    closed.fetch_add(1, Ordering::SeqCst);
                }
                ChannelEvent::Stalled { .. } => {}
            }),
        );
        peers.push(theirs);
        channels.push(ch);
    }

    // Every simulated worker speaks once; the reactor must demultiplex all
    // 256 inbound frames.
    for peer in &mut peers {
        write_message(peer, &Message::Ping).expect("peer write");
    }
    let give_up = Instant::now() + Duration::from_secs(30);
    while frames.load(Ordering::SeqCst) < N {
        assert!(
            Instant::now() < give_up,
            "only {}/{N} frames demultiplexed",
            frames.load(Ordering::SeqCst)
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    // Every channel takes an outbound frame and the reactor drains it.
    let mut pong = Vec::new();
    write_message(&mut pong, &Message::Pong).expect("encode");
    for ch in &channels {
        ch.send_bytes(&pong).expect("send");
    }
    for ch in &channels {
        assert!(
            ch.wait_outbox_below(0, Duration::from_secs(10)),
            "outbox for {} never drained",
            ch.name()
        );
    }

    // The whole fleet is served by exactly one poll thread; the legacy
    // thread-per-connection shape would need 256 readers here.
    let tc = transport::thread_counts().expect("/proc thread probe");
    assert_eq!(tc.reactor, 1, "one reactor must serve all {N} channels: {tc:?}");
    assert_eq!(tc.readers, 0, "zero per-seat readers allowed: {tc:?}");

    // Teardown: peers hang up; every channel reports Closed exactly once.
    drop(peers);
    let give_up = Instant::now() + Duration::from_secs(30);
    while closed.load(Ordering::SeqCst) < N {
        assert!(
            Instant::now() < give_up,
            "only {}/{N} channels reported Closed",
            closed.load(Ordering::SeqCst)
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    for ch in &channels {
        ch.close();
    }
}
