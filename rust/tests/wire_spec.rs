//! WIRE.md ↔ code lockstep.
//!
//! WIRE.md at the repository root is the normative wire-protocol spec;
//! `rust/src/ipc/` is the reference implementation.  These tests parse the
//! spec's machine-readable tables (each preceded by a `<!-- table:name -->`
//! marker) and assert them equal, entry by entry, to the in-code tag
//! tables and constants — so editing either side alone fails CI.
//!
//! Also here: the PR's headline acceptance check — bytes on the wire for a
//! repeated 1 MB tensor payload MUST be strictly lower under v6
//! compression + interning than under the v5-equivalent raw resend path.

use rustures::api::env::Env;
use rustures::api::expr::Expr;
use rustures::api::value::{Tensor, Value};
use rustures::ipc::intern::{self, SeatLedger};
use rustures::ipc::{codec, frame, wire, Message, TaskOpts, TaskSpec, PROTOCOL_VERSION};

const SPEC: &str = include_str!("../../WIRE.md");

/// Rows of the markdown table that follows `<!-- table:name -->`: each
/// `| a | b |` data row as `(a, b)`, header and `|---|` separator skipped.
fn spec_table(name: &str) -> Vec<(String, String)> {
    let marker = format!("<!-- table:{name} -->");
    let mut lines = SPEC
        .lines()
        .skip_while(|l| l.trim() != marker)
        .skip(1)
        .skip_while(|l| !l.trim_start().starts_with('|'));
    let mut rows = Vec::new();
    // Header row + separator row, then data rows until the table ends.
    let header = lines.next().unwrap_or_else(|| panic!("no table after {marker}"));
    assert!(header.starts_with('|'), "no table after {marker}");
    let sep = lines.next().unwrap_or_default();
    assert!(sep.contains("---"), "malformed table after {marker}");
    for line in lines {
        let line = line.trim();
        if !line.starts_with('|') {
            break;
        }
        let cells: Vec<&str> = line.trim_matches('|').split('|').map(str::trim).collect();
        assert_eq!(cells.len(), 2, "row {line:?} in table {name} is not two columns");
        rows.push((cells[0].to_string(), cells[1].to_string()));
    }
    assert!(!rows.is_empty(), "table {name} has no data rows");
    rows
}

/// A `| tag | name |` spec table as `(u8, name)` pairs.
fn spec_tag_table(name: &str) -> Vec<(u8, String)> {
    spec_table(name)
        .into_iter()
        .map(|(tag, n)| (tag.parse::<u8>().unwrap_or_else(|_| panic!("bad tag {tag:?} in {name}")), n))
        .collect()
}

fn assert_table_matches(spec_name: &str, code: &[(u8, &str)]) {
    let spec = spec_tag_table(spec_name);
    assert_eq!(
        spec.len(),
        code.len(),
        "WIRE.md table {spec_name} has {} rows, code table has {}",
        spec.len(),
        code.len()
    );
    for ((stag, sname), (ctag, cname)) in spec.iter().zip(code) {
        assert_eq!((stag, sname.as_str()), (ctag, *cname), "drift in table {spec_name}");
    }
}

#[test]
fn spec_tag_tables_match_code() {
    assert_table_matches("frame-kinds", wire::FRAME_KIND_TABLE);
    assert_table_matches("values", wire::VALUE_TAG_TABLE);
    assert_table_matches("exprs", wire::EXPR_TAG_TABLE);
    assert_table_matches("plans", wire::PLAN_TAG_TABLE);
    assert_table_matches("prims", wire::PRIM_TAG_TABLE);
    assert_table_matches("emits", wire::EMIT_TAG_TABLE);
    assert_table_matches("conditions", wire::CONDITION_TAG_TABLE);
    assert_table_matches("rng-dists", wire::RNG_DIST_TABLE);
    assert_table_matches("codecs", wire::CODEC_TABLE);
}

#[test]
fn spec_constants_match_code() {
    let spec: std::collections::HashMap<String, u64> = spec_table("constants")
        .into_iter()
        .map(|(k, v)| {
            let parsed = v.parse::<u64>().unwrap_or_else(|_| panic!("bad value {v:?} for {k}"));
            (k, parsed)
        })
        .collect();
    let code: &[(&str, u64)] = &[
        ("PROTOCOL_VERSION", u64::from(PROTOCOL_VERSION)),
        ("MAX_FRAME", u64::from(frame::MAX_FRAME)),
        ("COMPRESS_MIN", codec::COMPRESS_MIN as u64),
        ("INTERN_MIN", intern::INTERN_MIN as u64),
        ("DEFAULT_INTERN_CAP", intern::DEFAULT_INTERN_CAP as u64),
        ("CODEC_RAW", u64::from(codec::CODEC_RAW)),
        ("CODEC_DELTA_RLE", u64::from(codec::CODEC_DELTA_RLE)),
    ];
    assert_eq!(spec.len(), code.len(), "WIRE.md constants table row count drifted");
    for (name, want) in code {
        assert_eq!(spec.get(*name), Some(want), "WIRE.md constant {name} drifted");
    }
}

#[test]
fn spec_mentions_every_frame_kind_by_name() {
    // Beyond the table itself: the prose must discuss each frame kind.
    for (_, name) in wire::FRAME_KIND_TABLE {
        assert!(SPEC.contains(name), "WIRE.md never mentions frame kind {name}");
    }
}

/// The headline acceptance criterion: resending a task with a 1 MB tensor
/// global four times costs strictly fewer bytes on the wire under v6
/// (compression + interning through one seat ledger) than under the
/// v5-equivalent path (uncompressed, full payload every time).
#[test]
fn one_megabyte_payload_resends_shrink_under_v6() {
    let n = (1 << 20) / 4; // 1 MiB of f32s
    let data: Vec<f32> = (0..n).map(|i| (i % 251) as f32).collect();
    let tensor = Value::Tensor(
        Tensor::from_shared(vec![n], std::sync::Arc::from(data.into_boxed_slice())).unwrap(),
    );
    let mut globals = Env::new();
    globals.insert("weights", tensor);
    let task = TaskSpec {
        id: "f-0-1".to_string(),
        expr: Expr::var("weights"),
        globals,
        opts: TaskOpts::default(),
    };

    // v5-equivalent baseline: raw (uncompressed) frame, full payload each
    // launch, 4 launches.
    let raw = wire::encode_message_opts(&Message::Task(task.clone()), false).len();
    let baseline = 4 * raw;

    // v6: one seat, interning on — first frame provides the blob, the
    // next three reference it by digest.
    let mut ledger = SeatLedger::new();
    let v6: usize =
        (0..4).map(|_| wire::encode_task_message_interned(&task, &mut ledger).len()).sum();

    assert!(
        v6 < baseline,
        "v6 bytes on wire ({v6}) must beat the raw resend baseline ({baseline})"
    );
    // The win must be structural, not marginal: three of the four sends
    // collapse to ~17-byte references, so v6 stays under half the baseline
    // even if the provide frame itself were incompressible.
    assert!(v6 * 2 < baseline, "v6 ({v6}) should be well under half the baseline ({baseline})");
}
