#!/usr/bin/env bash
# Run the perf-trajectory benches (E1 overhead, E3 chunking, E11 resolve,
# E12 recovery, E13 capacity, E14 liveness, E15 analysis, E16 wire,
# E17 cache, E18 transport) and write machine-readable
# BENCH_overhead.json / BENCH_chunking.json / BENCH_resolve.json /
# BENCH_recovery.json / BENCH_capacity.json / BENCH_liveness.json /
# BENCH_analysis.json / BENCH_wire.json / BENCH_cache.json /
# BENCH_transport.json at the repo root, so every PR can diff perf
# against the previous one.
#
# Usage:
#   scripts/bench.sh           # smoke mode (reduced iterations; CI default)
#   scripts/bench.sh full      # full iteration counts
#
# Schema of the emitted files: see BENCH.md.

set -euo pipefail
cd "$(dirname "$0")/.."

mode="${1:-smoke}"
export BENCH_OUT="$PWD"
if [ "$mode" = "smoke" ]; then
    export BENCH_SMOKE=1
else
    unset BENCH_SMOKE || true
fi

# The worker binary must exist for the multiprocess/cluster/batch backends.
cargo build --release --manifest-path rust/Cargo.toml

cargo bench --manifest-path rust/Cargo.toml --bench overhead
cargo bench --manifest-path rust/Cargo.toml --bench chunking
cargo bench --manifest-path rust/Cargo.toml --bench resolve
cargo bench --manifest-path rust/Cargo.toml --bench recovery
cargo bench --manifest-path rust/Cargo.toml --bench scaling
cargo bench --manifest-path rust/Cargo.toml --bench analysis
cargo bench --manifest-path rust/Cargo.toml --bench wire
cargo bench --manifest-path rust/Cargo.toml --bench cache
cargo bench --manifest-path rust/Cargo.toml --bench transport

echo
echo "== bench artifacts =="
ls -l BENCH_overhead.json BENCH_chunking.json BENCH_resolve.json BENCH_recovery.json \
      BENCH_capacity.json BENCH_liveness.json BENCH_analysis.json BENCH_wire.json \
      BENCH_cache.json BENCH_transport.json
